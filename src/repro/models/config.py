"""Model-zoo configuration.

One ``ModelConfig`` describes any architecture in the assigned pool; the
family-specific builders in ``transformer.py`` / ``hybrid.py`` / ``encdec.py``
consume it.  Layer heterogeneity (gemma2/gemma3 local:global alternation,
zamba2 mamba:shared-attention interleave) is expressed as a repeating
``pattern`` so the runtime can ``lax.scan`` over pattern *repeats* — keeping
the traced HLO O(pattern length), not O(depth), which is what makes the
512-virtual-device dry-run compiles tractable (DESIGN §6).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

LayerKind = Literal["global_attn", "local_attn", "mamba", "shared_attn"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 4096        # dispatch group (bounds one-hot matmul cost)
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_kernel: int = 4
    chunk: int = 256              # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1
    # Use the fused Pallas SSD within-chunk kernel (kernels/ssd.py) instead
    # of the XLA einsum chain (requires n_groups == 1).  TPU-only in
    # production (interpret-mode on CPU, for tests).
    use_kernel: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads
    # Repeating layer pattern; cycled n_layers/len(pattern) times.
    pattern: tuple[LayerKind, ...] = ("global_attn",)
    window: int = 4096                   # local_attn window size
    mlp_act: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    qk_norm: bool = False
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    scale_embedding: bool = False        # gemma: embed × sqrt(d_model)
    use_post_norm: bool = False          # gemma2/3 pre+post norm sandwich
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    shared_attn_every: int = 0           # zamba2: shared block cadence
    shared_attn_window: int | None = None  # window for the shared block
    # Encoder-decoder (audio family): encoder depth; decoder uses n_layers.
    n_encoder_layers: int = 0
    # Modality frontend stub: number of prefix embedding tokens consumed.
    frontend: Literal["none", "vision_stub", "audio_stub"] = "none"
    # Blockwise (flash-style) attention: full-sequence attention switches to
    # the streaming two-level block scan when S ≥ flash_threshold.  None →
    # always dense-materialised scores (the naive baseline; see §Perf).
    flash_threshold: int | None = None
    flash_block: int = 512
    # Use the Pallas flash-attention kernel (kernels/flash_attention.py)
    # instead of the jnp block-scan when the flash path triggers.  TPU-only
    # in production (interpret-mode on CPU, for tests).
    flash_kernel: bool = False
    # Chunked-vocab logsumexp in the CE loss: peak f32 logits memory drops
    # ~chunks× (checkpointed scan over vocab chunks).  1 → single pass.
    ce_vocab_chunks: int = 1
    param_dtype: jnp.dtype = jnp.bfloat16
    # Citation of the source model card / paper for the exact numbers.
    source: str = ""

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, \
            (self.name, self.n_layers, self.pattern)
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    def with_sliding_windows(self, window: int = 4096) -> "ModelConfig":
        """long_500k override: every attention layer becomes sliding-window
        so the KV cache is bounded (DESIGN §4 policy)."""
        new_pattern = tuple(
            "local_attn" if k == "global_attn" else k for k in self.pattern)
        return dataclasses.replace(self, pattern=new_pattern,
                                   window=min(self.window, window),
                                   shared_attn_window=window)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One benchmark input shape from the assignment table."""
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k":   InputShape("long_500k", 524_288, 1, "decode"),
}
