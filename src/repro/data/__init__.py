from repro.data import fmri, store, synthetic  # noqa: F401
from repro.data.store import RunStore, StoreError  # noqa: F401
