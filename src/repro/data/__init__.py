from repro.data import fmri, synthetic  # noqa: F401
