"""Synthetic batch construction for every architecture family and shape.

``batch_spec`` is the single source of truth for what a (family × shape)
batch looks like; it returns ShapeDtypeStructs (dry-run) and
``make_batch`` materialises the same spec with random data (smoke tests,
examples).  Modality frontends are stubs per the assignment: VLM batches
carry precomputed patch embeddings, audio batches carry precomputed frame
embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import InputShape, ModelConfig


def batch_spec(cfg: ModelConfig, batch: int, seq: int,
               kind: str = "train") -> dict:
    """ShapeDtypeStruct tree describing one input batch."""
    if kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}
    if cfg.family == "vlm":
        half = seq // 2
        return {
            "prefix_embeds": jax.ShapeDtypeStruct((batch, half, cfg.d_model),
                                                  jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((batch, seq - half), jnp.int32),
        }
    if cfg.family == "audio":
        if kind == "prefill":
            # Encoder-heavy prefill: the whole sequence is source frames.
            return {
                "src_embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                                   jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
            }
        half = seq // 2
        return {
            "src_embeds": jax.ShapeDtypeStruct((batch, half, cfg.d_model),
                                               jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((batch, seq - half), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}


def make_batch(key: jax.Array, cfg: ModelConfig, batch: int, seq: int,
               kind: str = "train") -> dict:
    """Materialise ``batch_spec`` with random contents."""
    spec = batch_spec(cfg, batch, seq, kind)
    out = {}
    for name, s in spec.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, s.shape, 0, cfg.vocab,
                                           dtype=s.dtype)
        else:
            out[name] = jax.random.normal(sub, s.shape, jnp.float32) \
                .astype(s.dtype)
    return out


class TokenStream:
    """Deterministic shard-aware synthetic token stream (training driver).

    Mimics a production data pipeline: infinite iterator of fixed-shape
    batches, seeded per (epoch, step, shard) so every data-parallel shard
    reads disjoint data and restarts are reproducible from the step index.
    """

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0, shard: int = 0, n_shards: int = 1):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.seed, self.shard, self.n_shards = seed, shard, n_shards

    def batch_at(self, step: int) -> dict:
        key = jax.random.PRNGKey(self.seed)
        key = jax.random.fold_in(key, step * self.n_shards + self.shard)
        return make_batch(key, self.cfg, self.batch, self.seq, "train")

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
