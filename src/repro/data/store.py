"""Out-of-core run store: memory-mapped fMRI runs behind a JSON manifest.

The paper's whole-brain workload (Table 1: n≈60k TRs × t≈264k targets per
subject) does not fit in device — or often host — memory, which is why its
Batch-MultiOutput design streams target batches across workers.  ``RunStore``
is the row-streaming half of that story: each acquisition *run* is written
once as a pair of ``.npy`` shards (``X``: stimulus features, ``Y``: BOLD
targets) and thereafter only ever *memory-mapped*, so ``iter_chunks`` hands
out zero-copy row batches whose resident footprint is one chunk, never
``(n, p)``.

Layout on disk::

    <root>/manifest.json          # shapes, dtypes, row offsets, fold split
    <root>/<run_id>.X.npy         # (n_run, p) feature shard
    <root>/<run_id>.Y.npy         # (n_run, t) target shard

Design points:

* **Global row order is the manifest's run order.**  Runs are concatenated
  at their recorded ``row_offset``; the k-fold split used downstream
  (``foldstats.fold_bounds`` over ``n_total``) is recorded in the manifest
  at write time so every consumer — in-memory, chunked, sharded-chunked —
  derives the identical fold assignment.
* **Read paths are read-only.**  ``open()`` maps shards with
  ``mmap_mode="r"``; writing through a served chunk raises, so a streaming
  fit can never corrupt the store it is reading.
* **Validation is eager.**  ``open()`` cross-checks every shard's header
  shape/dtype against the manifest and the run offsets against each other;
  a missing shard, a shape/dtype mismatch, or overlapping row ranges raise
  ``StoreError`` before any fit starts.
* **Chunks respect nothing but row order.**  ``iter_chunks`` slices freely
  across run boundaries (a chunk may span two runs) and across fold
  boundaries — the fold-stats accumulator splits at fold bounds itself —
  so chunk size is purely a memory/throughput knob.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import threading
from typing import Iterator

import numpy as np

from repro import obs
from repro.data.fmri import SubjectSpec
from repro.resilience import cleanup
from repro.resilience.policy import FaultPolicy, classify_default, retry_call

MANIFEST_NAME = "manifest.json"
_MANIFEST_VERSION = 1


class StoreError(ValueError):
    """Manifest/shard inconsistency (missing file, shape/dtype mismatch,
    overlapping or gapped row ranges)."""


def _dtype_from_name(name: str) -> np.dtype:
    """Resolve a manifest dtype name, including the ml_dtypes extras
    (``bfloat16``) that plain ``np.dtype(...)`` does not know by name."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _storage_dtype(dtype: np.dtype) -> np.dtype:
    """On-disk dtype for a logical dtype.

    ``np.save`` demotes non-native dtypes (ml_dtypes ``bfloat16``) to raw
    void records that neither numpy nor JAX will touch afterwards, so such
    shards are stored as same-width unsigned bit patterns and viewed back
    at read time — the memmap view is still zero-copy.
    """
    if dtype.kind == "V" or dtype.name == "bfloat16":
        return np.dtype(f"u{dtype.itemsize}")
    return dtype


@dataclasses.dataclass(frozen=True)
class RunEntry:
    """One acquisition run inside the store (one X/Y shard pair)."""

    run_id: str
    row_offset: int     # first global row of this run
    n_rows: int

    @property
    def row_end(self) -> int:
        return self.row_offset + self.n_rows


def _shard_paths(root: str, run_id: str) -> tuple[str, str]:
    return (os.path.join(root, f"{run_id}.X.npy"),
            os.path.join(root, f"{run_id}.Y.npy"))


def _normalize_dtype(dtype) -> np.dtype | None:
    if dtype is None:
        return None
    return _dtype_from_name(dtype) if isinstance(dtype, str) \
        else np.dtype(dtype)


@dataclasses.dataclass
class PrefetchStats:
    """Where a prefetched stream spent its waiting time.

    ``read_stall_s`` is consumer time blocked on an empty queue (the disk
    reader was the bottleneck); ``compute_stall_s`` is reader time blocked
    on a full queue (compute was the bottleneck — the overlap is working).
    A well-overlapped stream has one of the two ≈ the pipeline imbalance
    and the other ≈ 0; both ≈ 0 means the stream finished before either
    side ever waited.

    Every field is DERIVED from the prefetcher's observability spans
    (``prefetch.stage`` / ``prefetch.wait`` / ``prefetch.compute_stall``
    via ``obs.timed``) — the stats and a recorded trace are two views of
    the same measurements, never parallel bookkeeping.
    """

    chunks: int = 0
    bytes_staged: int = 0
    read_stall_s: float = 0.0
    compute_stall_s: float = 0.0

    def to_dict(self) -> dict:
        """Shared metrics-snapshot schema (``repro.obs``): flat
        snake_case fields, JSON-serialisable — what benches consume."""
        return {"schema": obs.SCHEMA_VERSION, "kind": "prefetch",
                "chunks": int(self.chunks),
                "bytes_staged": int(self.bytes_staged),
                "read_stall_s": float(self.read_stall_s),
                "compute_stall_s": float(self.compute_stall_s)}


class ChunkPrefetcher:
    """Double-buffered background reader over ``RunStore.iter_chunks``.

    A daemon thread walks the ordinary (synchronous) chunk iterator and
    *stages* each chunk — memmap page-in plus any dtype conversion — into
    one of ``depth + 2`` reusable pre-allocated host buffers (the CPU
    analogue of pinned staging memory), then hands it over through a
    bounded queue of ``depth``.  While the consumer runs the device
    accumulation on chunk *i*, the reader is already faulting in chunk
    *i+1*: the stream runs at the speed of the slower side, not their sum.

    Contracts:

    * **Bit-identical**: staging is a straight copy, so chunk order,
      shapes, and values are exactly the synchronous iterator's.
    * **Bounded residency**: ``depth + 2`` buffers of ``chunk_rows`` rows,
      allocated lazily on first iteration and released when the stream is
      exhausted or closed.  ``depth`` queued + 1 held by the consumer + 1
      being staged never exceeds the pool, so a yielded view is valid
      until the NEXT ``next()`` call — consumers that keep chunks must
      copy (every in-repo consumer converts or reduces immediately).
    * **Exceptions propagate**: a reader-thread failure re-raises in the
      consumer at the point of ``next()``.
    * **Early shutdown**: ``close()`` (also called on ``__del__`` and by
      the streaming consumers' ``finally``) stops the reader thread and
      frees the buffers even mid-stream — an aborted fit leaks nothing.

    Yielded arrays are read-only views into the staging buffers, matching
    the read-only memmap semantics of the synchronous path.
    """

    _SENTINEL = object()

    def __init__(self, store: "RunStore", chunk_rows: int, *,
                 dtype: np.dtype | None, row_range: tuple[int, int] | None,
                 col_range: tuple[int, int] | None = None,
                 col_range_x: tuple[int, int] | None = None,
                 depth: int = 2):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self._store = store
        self._chunk_rows = chunk_rows
        self._dtype = _normalize_dtype(dtype)
        self._row_range = row_range
        self._col_range = col_range
        self._col_range_x = col_range_x
        self._depth = depth
        self.stats = PrefetchStats()
        # Hoisted global-metric instruments (one dict lookup each, here,
        # instead of one per staged chunk on the hot path).
        _m = obs.get_metrics()
        self._m_bytes = _m.counter("bytes_staged")
        self._m_chunks = _m.counter("chunks_staged")
        self._m_read_stall = _m.counter("read_stall_s")
        self._m_compute_stall = _m.counter("compute_stall_s")
        self._queue: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._bufs: list[tuple[np.ndarray, np.ndarray]] | None = None
        self._done = False

    # -- iterator protocol ---------------------------------------------------
    def __iter__(self) -> "ChunkPrefetcher":
        return self

    def _start(self) -> None:
        dt_x = self._dtype or self._store.dtype_x
        dt_y = self._dtype or self._store.dtype_y
        clo, chi = (self._col_range if self._col_range is not None
                    else (0, self._store.t))
        xlo, xhi = (self._col_range_x if self._col_range_x is not None
                    else (0, self._store.p))
        n_buf = self._depth + 2
        self._bufs = [
            (np.empty((self._chunk_rows, xhi - xlo), dt_x),
             np.empty((self._chunk_rows, chi - clo), dt_y))
            for _ in range(n_buf)]
        self._thread = threading.Thread(
            target=self._reader, name="runstore-prefetch", daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        """Stop-aware bounded put; returns False when closed mid-stream.
        Time spent blocked here is compute-stall (queue full = the device
        side is behind) — one ``prefetch.compute_stall`` span, from which
        ``stats.compute_stall_s`` is derived."""
        try:
            self._queue.put_nowait(item)
            return True
        except queue.Full:
            pass
        with obs.timed("prefetch.compute_stall") as t:
            ok = False
            while not self._stop.is_set():
                try:
                    self._queue.put(item, timeout=0.05)
                    ok = True
                    break
                except queue.Full:
                    continue
        if ok:
            self.stats.compute_stall_s += t.dur_s
            self._m_compute_stall.inc(t.dur_s)
        return ok

    def _reader(self) -> None:
        """Walk the synchronous iterator, staging each chunk into the pool.

        Resilience: when the backing store carries a ``fault_policy``, a
        transient mid-stream failure does not kill the stream — the
        reader backs off (deterministic jitter, see
        ``repro.resilience.policy``) and RESTARTS the synchronous
        iterator at the first unconsumed chunk.  Chunks are uniformly
        ``chunk_rows`` rows except the ragged tail, so chunk ``seq``
        always starts at global row ``lo + seq·chunk_rows`` and the
        restarted stream yields the identical remaining sequence —
        bit-identity survives the retry.  The attempt counter resets on
        every staged chunk, so only *consecutive* failures exhaust the
        policy; a give-up (or any permanent error) propagates to the
        consumer exactly as before.
        """
        from repro.resilience.policy import classify_default

        policy = getattr(self._store, "fault_policy", None)
        lo, hi = (self._row_range if self._row_range is not None
                  else (0, self._store.n_total))
        metrics = obs.get_metrics()
        seq = 0
        attempt = 0
        burst_start = None
        try:
            while True:
                try:
                    for X_c, Y_c in self._store._iter_chunks_sync(
                            self._chunk_rows, self._dtype,
                            lo + seq * self._chunk_rows, hi,
                            self._col_range, self._col_range_x):
                        if self._stop.is_set():
                            return
                        bx, by = self._bufs[seq % len(self._bufs)]
                        m = X_c.shape[0]
                        # The staging copy (memmap page-in + dtype
                        # conversion) is one ``prefetch.stage`` span;
                        # bytes_staged derives from the same region.
                        with obs.timed("prefetch.stage", chunk=seq) as t:
                            np.copyto(bx[:m], X_c)
                            np.copyto(by[:m], Y_c)
                            staged = bx[:m].nbytes + by[:m].nbytes
                            t.set(bytes=staged)
                        vx, vy = bx[:m].view(), by[:m].view()
                        vx.flags.writeable = False
                        vy.flags.writeable = False
                        self.stats.bytes_staged += staged
                        self._m_bytes.inc(staged)
                        if not self._put((vx, vy)):
                            return
                        seq += 1
                        attempt = 0
                        burst_start = None
                    break
                except BaseException as exc:         # noqa: BLE001
                    if self._stop.is_set():
                        return
                    if policy is None or not classify_default(exc):
                        raise
                    attempt += 1
                    now = policy.clock()
                    if burst_start is None:
                        burst_start = now
                    out_of_time = (policy.deadline_s is not None and
                                   now - burst_start >= policy.deadline_s)
                    if attempt >= policy.max_attempts or out_of_time:
                        metrics.counter("io_giveups",
                                        op="prefetch.read").inc()
                        obs.instant("retry.giveup", op="prefetch.read",
                                    attempt=attempt)
                        raise
                    metrics.counter("io_retries", op="prefetch.read").inc()
                    delay = policy.delay_for("prefetch.read", attempt)
                    with obs.span("retry.backoff", op="prefetch.read",
                                  attempt=attempt,
                                  delay_s=round(delay, 6)):
                        if delay > 0.0:
                            policy.sleep(delay)
            self._put(self._SENTINEL)
        except BaseException as exc:                 # noqa: BLE001
            self._put(exc)

    def __next__(self):
        if self._done:
            raise StopIteration
        if self._thread is None:
            self._start()
        # Consumer-side block on an empty queue: one ``prefetch.wait``
        # span, from which ``stats.read_stall_s`` is derived.
        with obs.timed("prefetch.wait") as t:
            item = self._queue.get()
        self.stats.read_stall_s += t.dur_s
        self._m_read_stall.inc(t.dur_s)
        if item is self._SENTINEL:
            self.close()
            raise StopIteration
        if isinstance(item, BaseException):
            self.close()
            raise item
        self.stats.chunks += 1
        self._m_chunks.inc()
        obs.instant("prefetch.yield", chunk=self.stats.chunks - 1)
        return item

    def close(self) -> None:
        """Stop the reader, drain the queue, release the staging buffers."""
        self._done = True
        self._stop.set()
        while True:                     # unblock a reader stuck on put()
            try:
                self._queue.get_nowait()
            except queue.Empty:
                break
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        self._bufs = None

    def __del__(self):
        try:
            self.close()
        except Exception:               # interpreter teardown
            pass


def _read_npy_header(path: str) -> tuple[tuple[int, ...], np.dtype]:
    """Shape/dtype from the .npy header alone (no data page-in)."""
    with open(path, "rb") as f:
        version = np.lib.format.read_magic(f)
        shape, _, dtype = np.lib.format._read_array_header(f, version)
    return shape, dtype


class RunStore:
    """On-disk (X, Y) row store — write runs once, stream them many times.

    Writing (builds/extends the manifest)::

        store = RunStore.create(path, n_folds=5)
        store.write(X_run1, Y_run1, "ses-001_run-1")
        store.write(X_run2, Y_run2, "ses-001_run-2")

    Streaming (read-only memmaps; resident set = one chunk)::

        store = RunStore.open(path)
        for X_c, Y_c in store.iter_chunks(chunk_rows=4096):
            ...                        # np.ndarray views, zero-copy

    ``materialize_synthetic`` writes a ``data.fmri`` subject once so
    benchmarks and tests can re-stream it without regenerating.
    """

    def __init__(self, root: str, *, n_folds: int, dtype_x: np.dtype,
                 dtype_y: np.dtype, p: int | None, t: int | None,
                 runs: list[RunEntry], writable: bool,
                 fault_policy: FaultPolicy | None = None):
        self.root = root
        self.n_folds = n_folds
        self.dtype_x = np.dtype(dtype_x)
        self.dtype_y = np.dtype(dtype_y)
        self.p = p
        self.t = t
        self.runs = runs
        self._writable = writable
        #: transient-fault retry policy for shard reads (None = no retry).
        self.fault_policy = fault_policy

    # -- construction --------------------------------------------------------
    @classmethod
    def create(cls, root: str, *, n_folds: int = 5,
               dtype: np.dtype | str = np.float32) -> "RunStore":
        """Start an empty, writable store at ``root`` (created if missing)."""
        os.makedirs(root, exist_ok=True)
        # A crashed writer leaves `*.tmp-*` shard stubs / a manifest tmp
        # behind; reap them (age-gated) before validating emptiness.
        cleanup.reap_stale_staging(root)
        if os.path.exists(os.path.join(root, MANIFEST_NAME)):
            raise StoreError(f"store already exists at {root}; use open()")
        store = cls(root, n_folds=n_folds, dtype_x=np.dtype(dtype),
                    dtype_y=np.dtype(dtype), p=None, t=None, runs=[],
                    writable=True)
        store._write_manifest()
        return store

    @classmethod
    def open(cls, root: str, *, fault_policy: FaultPolicy | None = None
             ) -> "RunStore":
        """Open read-only and validate the manifest against the shards.

        ``fault_policy`` arms transient-fault retry on every subsequent
        shard mmap and on the prefetcher's chunk stream (see
        ``repro.resilience``); omitted, reads fail fast as before.
        """
        path = os.path.join(root, MANIFEST_NAME)
        if not os.path.exists(path):
            raise StoreError(f"no {MANIFEST_NAME} under {root}")
        with open(path) as f:
            m = json.load(f)
        if m.get("version") != _MANIFEST_VERSION:
            raise StoreError(f"unsupported manifest version {m.get('version')}")
        runs = [RunEntry(run_id=r["run_id"], row_offset=r["row_offset"],
                         n_rows=r["n_rows"]) for r in m["runs"]]
        store = cls(root, n_folds=m["n_folds"],
                    dtype_x=_dtype_from_name(m["dtype_x"]),
                    dtype_y=_dtype_from_name(m["dtype_y"]),
                    p=m["p"], t=m["t"], runs=runs, writable=False,
                    fault_policy=fault_policy)
        store._validate()
        return store

    # -- manifest ------------------------------------------------------------
    def _write_manifest(self) -> None:
        payload = {
            "version": _MANIFEST_VERSION,
            "n_folds": self.n_folds,
            "dtype_x": self.dtype_x.name,
            "dtype_y": self.dtype_y.name,
            "p": self.p,
            "t": self.t,
            "n_total": self.n_total,
            # The fold split is part of the data contract: every consumer
            # (in-memory, chunked, sharded) derives the same contiguous
            # k-fold assignment from (n_total, n_folds).
            "runs": [{"run_id": r.run_id, "row_offset": r.row_offset,
                      "n_rows": r.n_rows} for r in self.runs],
        }
        tmp = os.path.join(self.root, MANIFEST_NAME + ".tmp")
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        os.replace(tmp, os.path.join(self.root, MANIFEST_NAME))

    def _validate(self) -> None:
        """Cross-check every shard header against the manifest."""
        offset = 0
        for r in self.runs:
            if r.row_offset != offset:
                raise StoreError(
                    f"run {r.run_id!r}: row_offset {r.row_offset} overlaps or "
                    f"gaps the preceding runs (expected {offset})")
            offset = r.row_end
            for path, want_cols, want_dtype, name in (
                    (_shard_paths(self.root, r.run_id)[0], self.p,
                     _storage_dtype(self.dtype_x), "X"),
                    (_shard_paths(self.root, r.run_id)[1], self.t,
                     _storage_dtype(self.dtype_y), "Y")):
                if not os.path.exists(path):
                    raise StoreError(f"run {r.run_id!r}: missing {name} shard "
                                     f"{os.path.basename(path)}")
                shape, dtype = _read_npy_header(path)
                if shape != (r.n_rows, want_cols):
                    raise StoreError(
                        f"run {r.run_id!r}: {name} shard shape {shape} != "
                        f"manifest ({r.n_rows}, {want_cols})")
                if dtype != want_dtype:
                    raise StoreError(
                        f"run {r.run_id!r}: {name} shard dtype {dtype} != "
                        f"manifest {want_dtype}")

    # -- writing -------------------------------------------------------------
    def write(self, X: np.ndarray, Y: np.ndarray, run_id: str) -> RunEntry:
        """Append one run's rows; shards land as ``.npy``, manifest updates."""
        if not self._writable:
            raise StoreError("store was open()'d read-only; create() to write")
        X = np.ascontiguousarray(X, dtype=self.dtype_x)
        Y = np.ascontiguousarray(Y, dtype=self.dtype_y)
        if X.ndim != 2 or Y.ndim != 2 or X.shape[0] != Y.shape[0]:
            raise StoreError(f"need matching 2-D row blocks, got X{X.shape} "
                             f"Y{Y.shape}")
        if any(r.run_id == run_id for r in self.runs):
            raise StoreError(f"run {run_id!r} already written")
        if self.p is None:
            self.p, self.t = X.shape[1], Y.shape[1]
        elif (X.shape[1], Y.shape[1]) != (self.p, self.t):
            raise StoreError(f"run {run_id!r}: columns ({X.shape[1]}, "
                             f"{Y.shape[1]}) != store ({self.p}, {self.t})")
        entry = RunEntry(run_id=run_id, row_offset=self.n_total,
                         n_rows=X.shape[0])
        x_path, y_path = _shard_paths(self.root, run_id)
        # Crash-safe shard landing: stage as `<shard>.tmp-<pid>` then
        # atomic-rename, manifest LAST — a killed writer leaves only a
        # reapable tmp stub, never a manifest pointing at a torn shard.
        for path, arr, dt in ((x_path, X, self.dtype_x),
                              (y_path, Y, self.dtype_y)):
            tmp = f"{path}.tmp-{os.getpid()}"
            with open(tmp, "wb") as f:
                np.save(f, arr.view(_storage_dtype(dt)))
            os.replace(tmp, path)
        self.runs.append(entry)
        self._write_manifest()
        return entry

    def materialize_synthetic(self, spec: SubjectSpec, *, seed: int = 0,
                              rows_per_run: int | None = None) -> "RunStore":
        """Write a ``data.fmri`` subject once, split into run-sized shards.

        The subject's ``(n, p)``/``(n, t)`` arrays are generated run by run
        (each run gets its own fold of the PRNG key) so even materialisation
        never holds the full subject resident — the generator mirrors how a
        real scanning session arrives: one run at a time.
        """
        import jax
        from repro.data import fmri

        # Best-effort sweep of staging left by a previous crashed
        # materialisation into the same root (age-gated; live writers
        # are younger than the gate).
        cleanup.reap_stale_staging(self.root)
        rows_per_run = rows_per_run or spec.n
        key = jax.random.PRNGKey(seed)
        lo = 0
        while lo < spec.n:
            hi = min(lo + rows_per_run, spec.n)
            run_key = jax.random.fold_in(key, lo)
            run_spec = dataclasses.replace(spec, n=hi - lo)
            X, Y, _ = fmri.generate(run_key, run_spec)
            self.write(np.asarray(X), np.asarray(Y),
                       f"{spec.subject}_rows-{lo:08d}")
            lo = hi
        return self

    # -- reading -------------------------------------------------------------
    @property
    def n_total(self) -> int:
        return self.runs[-1].row_end if self.runs else 0

    @property
    def shape(self) -> tuple[int, int, int]:
        """(n_total, p, t)."""
        if self.p is None:
            raise StoreError("empty store has no shape yet")
        return self.n_total, self.p, self.t

    def nbytes_resident(self) -> int:
        """Bytes an in-memory fit would hold resident: full X plus Y."""
        n, p, t = self.shape
        return n * (p * self.dtype_x.itemsize + t * self.dtype_y.itemsize)

    def _mmap_raw(self, r: RunEntry) -> tuple[np.ndarray, np.ndarray]:
        """The raw (no-retry) shard mapping — the fault-injection seam."""
        x_path, y_path = _shard_paths(self.root, r.run_id)
        return (np.load(x_path, mmap_mode="r").view(self.dtype_x),
                np.load(y_path, mmap_mode="r").view(self.dtype_y))

    def _mmap(self, r: RunEntry) -> tuple[np.ndarray, np.ndarray]:
        if self.fault_policy is None:
            return self._mmap_raw(r)
        return retry_call(lambda: self._mmap_raw(r), self.fault_policy,
                          "store.mmap")

    def iter_chunks(self, chunk_rows: int, *, dtype: np.dtype | str | None
                    = None, row_range: tuple[int, int] | None = None,
                    col_range: tuple[int, int] | None = None,
                    col_range_x: tuple[int, int] | None = None,
                    prefetch: bool = False, prefetch_depth: int = 2
                    ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        """Yield ``(X_chunk, Y_chunk)`` row batches in global row order.

        Batches are views into the read-only memmaps (ZERO host copies —
        including when ``dtype`` names the stored dtype) unless ``dtype``
        requests a real cast or a chunk spans a run boundary (then the
        spanning rows are concatenated into a fresh array of ``chunk_rows``
        rows at most — still O(chunk), never O(n)).  ``row_range=(lo, hi)``
        restricts the stream to a global row window — the hook the sharded
        accumulation uses to give each shard its own contiguous slice.

        ``col_range=(clo, chi)`` restricts ``Y`` to a target-column window:
        chunks arrive as ``(X (m, p), Y (m, chi−clo))`` with only the
        window's pages ever touched — the target-axis streaming hook
        (``repro.wholebrain``).  ``col_range=(0, 0)`` yields zero-width
        ``Y`` chunks, which is how the X-only Gram pass streams the rows
        without reading one byte of the (much wider) target shards.
        ``col_range_x`` is the (rare) mirror for ``X``.  Whole-brain fits
        never window REAL feature columns (p ≪ t is the whole regime);
        its one use is ``col_range_x=(0, 0)`` — a Y-only pass that reads
        zero bytes of the feature shards while a host-side chunk cache
        supplies the ``X`` rows captured during an earlier stream (the
        single-X-pass composition in ``repro.wholebrain.solver``).

        ``prefetch=True`` returns a ``ChunkPrefetcher`` instead: a
        background reader stages the NEXT chunk into a reusable host
        buffer (bounded queue of ``prefetch_depth``) while the caller
        processes the current one — same chunks, same order, same values,
        overlapped with compute.  The prefetcher exposes ``stats``
        (reader-stall vs compute-stall time) and ``close()`` for early
        shutdown; its reader thread starts lazily on first iteration, so
        building many shard streams up front costs nothing until each is
        consumed.
        """
        if chunk_rows < 1:
            raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
        lo, hi = row_range if row_range is not None else (0, self.n_total)
        if not 0 <= lo <= hi <= self.n_total:
            raise ValueError(f"row_range {row_range} outside "
                             f"[0, {self.n_total}]")
        if col_range is not None:
            clo, chi = col_range
            if not 0 <= clo <= chi <= (self.t or 0):
                raise ValueError(f"col_range {col_range} outside "
                                 f"[0, {self.t}]")
        if col_range_x is not None:
            xlo, xhi = col_range_x
            if not 0 <= xlo <= xhi <= (self.p or 0):
                raise ValueError(f"col_range_x {col_range_x} outside "
                                 f"[0, {self.p}]")
        dtype = _normalize_dtype(dtype)
        if prefetch:
            return ChunkPrefetcher(self, chunk_rows, dtype=dtype,
                                   row_range=(lo, hi), col_range=col_range,
                                   col_range_x=col_range_x,
                                   depth=prefetch_depth)
        return self._iter_chunks_sync(chunk_rows, dtype, lo, hi, col_range,
                                      col_range_x)

    def _iter_chunks_sync(self, chunk_rows: int, dtype: np.dtype | None,
                          lo: int, hi: int,
                          col_range: tuple[int, int] | None = None,
                          col_range_x: tuple[int, int] | None = None
                          ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        pending_x: list[np.ndarray] = []
        pending_y: list[np.ndarray] = []
        pending = 0

        def cast(a: np.ndarray) -> np.ndarray:
            # Aligned-dtype fast path: hand back the memmap view itself —
            # no host copy for the common store-dtype == compute-dtype case.
            if dtype is None or a.dtype == dtype:
                return a
            return a.astype(dtype)

        for r in self.runs:
            if r.row_end <= lo or r.row_offset >= hi:
                continue
            Xm, Ym = self._mmap(r)
            if col_range is not None:
                # Column window of the memmap: a strided VIEW — zero-copy,
                # and reads fault in only the window's pages per row.
                Ym = Ym[:, col_range[0]:col_range[1]]
            if col_range_x is not None:
                Xm = Xm[:, col_range_x[0]:col_range_x[1]]
            s_lo = max(lo, r.row_offset) - r.row_offset
            s_hi = min(hi, r.row_end) - r.row_offset
            pos = s_lo
            while pos < s_hi:
                take = min(chunk_rows - pending, s_hi - pos)
                if pending:
                    pending_x.append(Xm[pos:pos + take])
                    pending_y.append(Ym[pos:pos + take])
                    pending += take
                    if pending == chunk_rows:
                        yield (cast(np.concatenate(pending_x)),
                               cast(np.concatenate(pending_y)))
                        pending_x, pending_y, pending = [], [], 0
                elif take == chunk_rows:
                    yield cast(Xm[pos:pos + take]), cast(Ym[pos:pos + take])
                else:
                    pending_x = [Xm[pos:pos + take]]
                    pending_y = [Ym[pos:pos + take]]
                    pending = take
                pos += take
        if pending:     # ragged tail
            yield (cast(np.concatenate(pending_x)),
                   cast(np.concatenate(pending_y)))

    def load(self, *, dtype: np.dtype | str | None = None
             ) -> tuple[np.ndarray, np.ndarray]:
        """Materialise the full (X, Y) — the in-memory reference path.

        Deliberately explicit: streaming consumers must never call this;
        it exists for parity tests and for ``BrainEncoder.fit(store=...)``
        when dispatch decides the problem fits the memory budget after all.
        """
        n, p, t = self.shape
        X = np.empty((n, p), self.dtype_x if dtype is None else dtype)
        Y = np.empty((n, t), self.dtype_y if dtype is None else dtype)
        for r in self.runs:
            Xm, Ym = self._mmap(r)
            X[r.row_offset:r.row_end] = Xm
            Y[r.row_offset:r.row_end] = Ym
        return X, Y


__all__ = ["ChunkPrefetcher", "PrefetchStats", "RunStore", "RunEntry",
           "StoreError", "MANIFEST_NAME"]
