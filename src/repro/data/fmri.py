"""CNeuroMod-shaped synthetic fMRI data generator (paper §2.1).

There is no network access in this environment, so the Friends dataset is
simulated with the *statistical shape* the paper reports: per-subject time
series Y (n time samples × t targets) generated from a planted linear model
on stimulus features X with target-dependent SNR, plus temporal drift and
noise — so brain-encoding recovers structure (visual-cortex-like high-SNR
targets) and the null permutation control collapses, mirroring §4.1-4.2.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SubjectSpec:
    """Mirror of paper Table 1 rows (defaults: truncated whole-brain)."""
    subject: str = "sub-01"
    n: int = 2_000      # time samples
    p: int = 256        # stimulus features
    t: int = 1_024      # brain targets
    frac_responsive: float = 0.25   # fraction of 'visual cortex' targets
    snr_responsive: float = 2.0
    drift_amp: float = 0.3
    tr_seconds: float = 1.49        # paper's fMRI TR


def generate(key: jax.Array, spec: SubjectSpec
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """→ (X (n,p) features, Y (n,t) BOLD targets, responsive mask (t,))."""
    kx, kw, kn, kd, km = jax.random.split(key, 5)
    X = jax.random.normal(kx, (spec.n, spec.p), jnp.float32)

    n_resp = int(spec.t * spec.frac_responsive)
    mask = jnp.arange(spec.t) < n_resp
    W = jax.random.normal(kw, (spec.p, spec.t), jnp.float32) / np.sqrt(spec.p)
    W = W * jnp.where(mask, 1.0, 0.0)[None, :]

    signal = X @ W * spec.snr_responsive
    noise = jax.random.normal(kn, (spec.n, spec.t), jnp.float32)
    # Slow drift (< 0.01 Hz), the confound the paper regresses out — kept in
    # the generator so the preprocessing path has something to remove.
    tt = jnp.arange(spec.n)[:, None] * spec.tr_seconds
    phase = jax.random.uniform(kd, (1, spec.t)) * 2 * jnp.pi
    drift = spec.drift_amp * jnp.sin(2 * jnp.pi * 0.003 * tt + phase)
    Y = signal + noise + drift
    # Per-target normalisation to zero mean / unit variance over time, as in
    # the paper's preprocessing (§2.1.4).
    Y = (Y - Y.mean(axis=0, keepdims=True)) / (Y.std(axis=0, keepdims=True)
                                               + 1e-6)
    return X, Y, mask


def detrend(Y: jax.Array, tr_seconds: float = 1.49,
            cutoff_hz: float = 0.01, n_basis: int | None = None) -> jax.Array:
    """Regress out a discrete-cosine basis of slow drifts (paper §2.1.4)."""
    n = Y.shape[0]
    if n_basis is None:
        n_basis = max(1, int(2 * n * tr_seconds * cutoff_hz))
    t = jnp.arange(n, dtype=jnp.float32)
    basis = jnp.stack(
        [jnp.cos(jnp.pi * (t + 0.5) * k / n) for k in range(1, n_basis + 1)],
        axis=1)                                          # (n, k)
    basis = basis / jnp.linalg.norm(basis, axis=0, keepdims=True)
    coef = basis.T @ Y
    return Y - basis @ coef
