"""Banded ridge encoding — feature-space selection (paper ref [13]).

Brain-encoding studies often concatenate several stimulus feature spaces
(multiple network layers, visual + audio embeddings, ...).  Banded ridge
gives each space its own λ, letting cross-validation *select* the
informative space instead of letting a shared λ over-shrink it.

Here: band 1 = 'visual network features' (drives the simulated fMRI),
band 2 = 'audio envelope features' (irrelevant).  Both fits go through
``BrainEncoder`` — setting ``bands=`` is all it takes to switch the
dispatcher onto the banded solver.

Run:  PYTHONPATH=src python examples/banded_encoding.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.encoding import BrainEncoder
from repro.core import scoring


def main():
    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n, p_vis, p_aud, t = 800, 48, 48, 128

    X_vis = jax.random.normal(k1, (n, p_vis), jnp.float32)
    X_aud = jax.random.normal(k2, (n, p_aud), jnp.float32)
    W = jax.random.normal(k3, (p_vis, t), jnp.float32) / np.sqrt(p_vis)
    Y = X_vis @ W + 0.7 * jax.random.normal(k4, (n, t))
    Y = (Y - Y.mean(0)) / (Y.std(0) + 1e-6)
    X = jnp.concatenate([X_vis, X_aud], axis=1)

    tr, te = scoring.train_test_split_indices(jax.random.PRNGKey(5), n)

    # Shared-λ baseline (the paper's RidgeCV) through the same estimator.
    shared = BrainEncoder(solver="ridge").fit(X[tr], Y[tr])
    r_shared = shared.score(X[te], Y[te])

    # Banded: one λ per feature space, random-search CV — just set bands=.
    banded = BrainEncoder(bands=(p_vis, p_aud), n_band_candidates=32,
                          n_folds=3, seed=6).fit(X[tr], Y[tr])
    assert banded.report_.decision.solver == "banded"
    r_banded = banded.score(X[te], Y[te])

    lam_vis, lam_aud = [float(v) for v in banded.report_.band_lambdas]
    print(f"shared-λ RidgeCV: λ = {float(shared.report_.best_lambda[0]):8.1f}"
          f"   test r = {r_shared.mean():.4f}")
    print(f"banded RidgeCV:   λ_visual = {lam_vis:8.1f}  "
          f"λ_audio = {lam_aud:8.1f}   test r = {r_banded.mean():.4f}")
    print(f"band norms: |W_visual| = "
          f"{float(jnp.linalg.norm(banded.weights_[:p_vis])):.2f}, "
          f"|W_audio| = "
          f"{float(jnp.linalg.norm(banded.weights_[p_vis:])):.2f}")
    assert lam_aud > lam_vis, "irrelevant band must be shrunk harder"
    assert float(r_banded.mean()) >= float(r_shared.mean()) - 0.01
    print("OK: banded ridge selected the informative feature space.")


if __name__ == "__main__":
    main()
