"""Distributed ridge comparison at example scale: single-node RidgeCV vs
MOR vs B-MOR on virtual devices — the paper's three implementations side by
side (Figures 8-10 in miniature), with wall-clock timings and the §3
complexity-model predictions.

All three run through the same ``BrainEncoder`` estimator; only the
``solver=`` override differs — the mesh construction and data placement that
used to be copied into this file now live in ``encoding.sharding``.

Run:  PYTHONPATH=src python examples/distributed_ridge.py
"""
import os
import subprocess
import sys
import time


def _reexec_with_devices(n: int = 8):
    if os.environ.get("_REPRO_DR_CHILD") == "1":
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["_REPRO_DR_CHILD"] = "1"
    raise SystemExit(subprocess.call([sys.executable] + sys.argv, env=env))


def main():
    _reexec_with_devices(8)
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import complexity, ridge
    from repro.encoding import BrainEncoder

    n, p, t = 512, 64, 512
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    X = jax.random.normal(k1, (n, p), jnp.float32)
    W = jax.random.normal(k2, (p, t), jnp.float32) / np.sqrt(p)
    Y = X @ W + 0.1 * jax.random.normal(k3, (n, t))
    cfg = ridge.RidgeCVConfig(n_folds=3)
    w = complexity.RidgeWorkload(n=n, p=p, t=t, r=len(cfg.lambdas),
                                 n_folds=cfg.n_folds)

    def timed(fn, *a, reps=3):
        fn(*a)  # compile
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(fn(*a))
        return (time.time() - t0) / reps

    c = 8
    print("NOTE: the 8 'devices' are virtual shards on ONE CPU core, so a "
          "measured\ntime is total WORK; ideal wall-clock on real chips = "
          "work / 8.\n")

    # 1. Mutualised single-shard RidgeCV (scikit-learn analog).
    single = BrainEncoder(solver="ridge", n_folds=3)
    t_single = timed(lambda: single.fit(X, Y).weights_)
    print(f"RidgeCV (1 shard, mutualised):    work {t_single*1e3:8.1f} ms")

    # 2. MOR across 8 shards (per-target recompute — paper Fig. 8).
    mor_enc = BrainEncoder(solver="mor", target_shards=c, n_folds=3)
    t_mor = timed(lambda: mor_enc.fit(X, Y).weights_, reps=1)
    print(f"MOR ({c} shards, t·T_M overhead):   work {t_mor*1e3:8.1f} ms   "
          f"wall≈{t_mor/c*1e3:7.1f} ms")

    # 3. B-MOR across 8 target shards (paper Alg. 1) — same t, same c.
    bmor_enc = BrainEncoder(solver="bmor", data_shards=1, target_shards=c,
                            n_folds=3)
    t_bmor = timed(lambda: bmor_enc.fit(X, Y).weights_)
    print(f"B-MOR ({c} target shards):          work {t_bmor*1e3:8.1f} ms   "
          f"wall≈{t_bmor/c*1e3:7.1f} ms")

    print(f"\nmeasured work MOR/B-MOR = {t_mor/t_bmor:5.1f}×   "
          f"(§3 model, work ratio: "
          f"{(complexity.t_w(w) + w.t*complexity.t_m(w)) / (complexity.t_w(w) + c*complexity.t_m(w)):.1f}×)")
    print(f"ideal B-MOR wall vs single shard: {t_bmor/c*1e3:.1f} vs "
          f"{t_single*1e3:.1f} ms  (DSU model: "
          f"{complexity.predicted_speedup_bmor(w, c):.1f}×)")
    print("→ MOR pays t·T_M, B-MOR pays c·T_M — the paper's Fig. 8/9 "
          "ordering.")


if __name__ == "__main__":
    main()
