"""Quickstart: the paper's pipeline in 60 lines.

1. Make CNeuroMod-shaped synthetic data (stimulus features X, fMRI Y).
2. Fit the SVD/eigh-mutualised multi-target RidgeCV (paper §2.3.1).
3. Evaluate with Pearson r on a held-out split + null-permutation control.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ridge, scoring
from repro.data import fmri


def main():
    # CNeuroMod-shaped data: 25% of targets are 'visual cortex' (responsive).
    spec = fmri.SubjectSpec(n=1200, p=128, t=512, frac_responsive=0.25)
    X, Y, responsive = fmri.generate(jax.random.PRNGKey(0), spec)
    Y = fmri.detrend(Y)  # regress out slow drifts (paper §2.1.4)

    # Paper §2.2.4: 90/10 random split, λ grid CV inside the training set.
    tr, te = scoring.train_test_split_indices(jax.random.PRNGKey(1), spec.n)
    res = ridge.ridge_cv(X[tr], Y[tr])
    print(f"selected λ = {float(res.best_lambda)} "
          f"(grid: {ridge.PAPER_LAMBDA_GRID})")

    preds = ridge.predict(X[te], res.weights)
    r = np.asarray(scoring.pearson_r(Y[te], preds))
    m = np.asarray(responsive)
    print(f"test Pearson r — responsive: {r[m].mean():.3f}, "
          f"non-responsive: {r[~m].mean():.3f}")

    null = scoring.null_permutation_scores(jax.random.PRNGKey(2), X[te],
                                           Y[te], res.weights, n_perms=10)
    print(f"null |r| (shuffled stimuli, paper §4.2): "
          f"{float(jnp.mean(jnp.abs(null))):.4f}")
    assert r[m].mean() > 5 * float(jnp.mean(jnp.abs(null)))
    print("OK: encoding is significant vs the null, as in the paper.")


if __name__ == "__main__":
    main()
