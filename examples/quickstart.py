"""Quickstart: the paper's pipeline through the unified estimator API.

1. Make CNeuroMod-shaped synthetic data (stimulus features X, fMRI Y).
2. ``pipeline.run`` — detrend (paper §2.1.4) → 90/10 split (§2.2.4) →
   standardize (train-fitted) → ``BrainEncoder`` fit (solver picked by
   complexity-driven dispatch; mutualised RidgeCV on one device) →
   Pearson-r evaluation with the §4.2 null-permutation control.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.data import fmri
from repro.encoding import pipeline


def main():
    # CNeuroMod-shaped data: 25% of targets are 'visual cortex' (responsive).
    spec = fmri.SubjectSpec(n=1200, p=128, t=512, frac_responsive=0.25)
    X, Y, responsive = fmri.generate(jax.random.PRNGKey(0), spec)

    # The whole paper pipeline in one call — no solver choice, no mesh
    # boilerplate; dispatch resolves from (n, p, t, device_count).
    state = pipeline.run(X, Y, n_perms=10)
    report, ev = state.report, state.evaluation

    d = report.decision
    print(f"dispatch picked: {d.solver} ({d.rationale})")
    print(f"selected λ = {report.best_lambda} (grid: {report.lambdas})")

    m = np.asarray(responsive)
    print(f"test Pearson r — responsive: {ev.pearson_r[m].mean():.3f}, "
          f"non-responsive: {ev.pearson_r[~m].mean():.3f}")
    print(f"null |r| (shuffled stimuli, paper §4.2): {ev.null_abs_r:.4f}")
    assert ev.pearson_r[m].mean() > 5 * ev.null_abs_r
    print("OK: encoding is significant vs the null, as in the paper.")


if __name__ == "__main__":
    main()
