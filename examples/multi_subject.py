"""Multi-subject brain encoding — the paper's N=6 CNeuroMod design (Fig. 4).

One B-MOR encoding model per subject on subject-specific synthetic data;
reports the per-subject encoding maps (responsive vs non-responsive r) and
the cross-subject consistency the paper highlights in §4.1 ("brain encoding
maps were highly consistent across subjects").

Run:  PYTHONPATH=src python examples/multi_subject.py
"""
import os
import subprocess
import sys

import numpy as np


def _reexec_with_devices(n: int = 8):
    if os.environ.get("_REPRO_MS_CHILD") == "1":
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["_REPRO_MS_CHILD"] = "1"
    raise SystemExit(subprocess.call([sys.executable] + sys.argv, env=env))


def main():
    _reexec_with_devices(8)
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import bmor, ridge, scoring
    from repro.data import fmri
    from repro.launch import mesh as mesh_lib

    mesh = mesh_lib.make_host_mesh(model=2)
    n_data = mesh.shape["data"]
    rows = []
    for i, subject in enumerate([f"sub-0{k}" for k in range(1, 7)]):
        spec = fmri.SubjectSpec(subject=subject, n=600, p=96, t=256,
                                frac_responsive=0.25,
                                snr_responsive=1.5 + 0.2 * i)  # subj. variety
        X, Y, mask = fmri.generate(jax.random.fold_in(jax.random.PRNGKey(0),
                                                      i), spec)
        Y = fmri.detrend(Y)
        tr, te = scoring.train_test_split_indices(
            jax.random.fold_in(jax.random.PRNGKey(1), i), spec.n)
        keep = (tr.shape[0] // n_data) * n_data
        Xs = jax.device_put(X[tr][:keep],
                            NamedSharding(mesh, P("data", None)))
        Ys = jax.device_put(Y[tr][:keep],
                            NamedSharding(mesh, P("data", "model")))
        res = bmor.bmor_fit(Xs, Ys, mesh)
        r = np.asarray(scoring.pearson_r(Y[te],
                                         ridge.predict(X[te], res.weights)))
        m = np.asarray(mask)
        rows.append((subject, r[m].mean(), r[~m].mean(), m))
        print(f"{subject}: r_responsive={r[m].mean():.3f}  "
              f"r_other={r[~m].mean():+.3f}  "
              f"λ per batch={np.asarray(res.best_lambda)}")

    # Cross-subject consistency (§4.1): the responsive 'region' is the same
    # target set for every subject — maps must agree.
    resp = np.array([a for _, a, _, _ in rows])
    other = np.array([b for _, _, b, _ in rows])
    print(f"\nacross subjects: responsive r = {resp.mean():.3f} ± "
          f"{resp.std():.3f};  non-responsive = {other.mean():+.3f}")
    assert resp.min() > 0.3 and abs(other).max() < 0.1
    print("OK: encoding maps are consistent across all 6 subjects "
          "(paper §4.1).")


if __name__ == "__main__":
    main()
