"""Multi-subject brain encoding — the paper's N=6 CNeuroMod design (Fig. 4).

One ``BrainEncoder`` per subject on subject-specific synthetic data (solver
and mesh layout resolved by dispatch — B-MOR on the 8 virtual devices);
reports the per-subject encoding maps (responsive vs non-responsive r) and
the cross-subject consistency the paper highlights in §4.1 ("brain encoding
maps were highly consistent across subjects").

Run:  PYTHONPATH=src python examples/multi_subject.py
"""
import os
import subprocess
import sys

import numpy as np


def _reexec_with_devices(n: int = 8):
    if os.environ.get("_REPRO_MS_CHILD") == "1":
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["_REPRO_MS_CHILD"] = "1"
    raise SystemExit(subprocess.call([sys.executable] + sys.argv, env=env))


def main():
    _reexec_with_devices(8)
    import jax
    from repro.data import fmri
    from repro.encoding import pipeline

    rows = []
    decision = None
    for i, subject in enumerate([f"sub-0{k}" for k in range(1, 7)]):
        spec = fmri.SubjectSpec(subject=subject, n=600, p=96, t=256,
                                frac_responsive=0.25,
                                snr_responsive=1.5 + 0.2 * i)  # subj. variety
        X, Y, mask = fmri.generate(jax.random.fold_in(jax.random.PRNGKey(0),
                                                      i), spec)
        # detrend → standardize → split → fit → evaluate, per subject.
        state = pipeline.run(X, Y, seed=i, n_perms=3)
        decision = state.report.decision
        r = state.evaluation.pearson_r
        m = np.asarray(mask)
        rows.append((subject, r[m].mean(), r[~m].mean(), m))
        print(f"{subject}: r_responsive={r[m].mean():.3f}  "
              f"r_other={r[~m].mean():+.3f}  "
              f"λ per batch={state.report.best_lambda}")

    print(f"\ndispatch (all subjects): {decision.solver} "
          f"mesh={decision.data_shards}x{decision.target_shards}")

    # Cross-subject consistency (§4.1): the responsive 'region' is the same
    # target set for every subject — maps must agree.
    resp = np.array([a for _, a, _, _ in rows])
    other = np.array([b for _, _, b, _ in rows])
    print(f"across subjects: responsive r = {resp.mean():.3f} ± "
          f"{resp.std():.3f};  non-responsive = {other.mean():+.3f}")
    assert resp.min() > 0.3 and abs(other).max() < 0.1
    print("OK: encoding maps are consistent across all 6 subjects "
          "(paper §4.1).")


if __name__ == "__main__":
    main()
