"""End-to-end driver: train a transformer backbone, then brain-encode its
hidden states with distributed B-MOR ridge — the paper's pipeline with a
modern feature extractor in place of VGG16.

Default is CPU-smoke scale.  ``--full`` trains the real qwen3-1.7b-class
config for a few hundred steps (sized for a TPU slice, not this container).

Run:  PYTHONPATH=src python examples/brain_encoding_e2e.py \
          [--arch qwen3-1.7b] [--steps 30] [--full]
"""
import argparse
import subprocess
import sys
import os


def _reexec_with_devices(n: int = 8):
    """B-MOR wants multiple shards; re-exec with virtual host devices."""
    if os.environ.get("_REPRO_E2E_CHILD") == "1":
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["_REPRO_E2E_CHILD"] = "1"
    raise SystemExit(subprocess.call([sys.executable] + sys.argv, env=env))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--full", action="store_true",
                    help="full config, few hundred steps (TPU-sized)")
    args = ap.parse_args()
    _reexec_with_devices(8)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import configs
    from repro.core import scoring
    from repro.data import synthetic
    from repro.encoding import BrainEncoder
    from repro.launch import mesh as mesh_lib
    from repro.launch.steps import build_train_step
    from repro.models import build_model
    from repro.models.config import InputShape
    from repro.optim import AdamWConfig, adamw_init

    cfg = configs.get_config(args.arch)
    steps = max(args.steps, 200) if args.full else args.steps
    if not args.full:
        cfg = configs.smoke(cfg)
    batch, seq = (8, 1024) if args.full else (4, 16)

    # ---- Phase 1: train the backbone on next-token prediction ----------
    mesh = mesh_lib.make_host_mesh(model=2)
    shape = InputShape("e2e", seq, batch, "train")
    bundle = build_train_step(cfg, mesh, shape, opt=AdamWConfig(lr=1e-3))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    with mesh:
        step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                          out_shardings=bundle.out_shardings,
                          donate_argnums=bundle.donate_argnums)
        stream = synthetic.TokenStream(cfg, batch, seq)
        first = last = None
        for step in range(steps):
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 stream.batch_at(step))
            loss = float(metrics["loss"])
            first = first if first is not None else loss
            last = loss
            if step % max(1, steps // 6) == 0:
                print(f"[train] step {step:4d} loss={loss:.4f}")
    print(f"[train] loss {first:.3f} → {last:.3f} over {steps} steps")

    # ---- Phase 2: extract features for 'movie frames' ------------------
    n_stim = 32  # stimulus batches
    feats = []
    hs = jax.jit(model.hidden_states)
    for i in range(n_stim):
        b = synthetic.make_batch(jax.random.PRNGKey(100 + i), cfg, batch, seq)
        h = hs(params, b)
        feats.append(np.asarray(h.reshape(-1, h.shape[-1]), np.float32))
    X = jnp.asarray(np.concatenate(feats, axis=0))
    X = (X - X.mean(0)) / (X.std(0) + 1e-6)
    print(f"[features] X = {X.shape} (backbone hidden states)")

    # ---- Phase 3: simulate fMRI responses + B-MOR encoding -------------
    t = 256
    key = jax.random.PRNGKey(7)
    W_true = jax.random.normal(key, (X.shape[1], t)) / np.sqrt(X.shape[1])
    responsive = jnp.arange(t) < t // 4
    W_true = W_true * responsive[None, :]
    Y = X @ W_true * 2.0 + jax.random.normal(jax.random.PRNGKey(8),
                                             (X.shape[0], t))
    Y = (Y - Y.mean(0)) / (Y.std(0) + 1e-6)

    tr, te = scoring.train_test_split_indices(jax.random.PRNGKey(9),
                                              X.shape[0])
    # The estimator owns row rounding, mesh construction, and device_put —
    # solver + layout resolved by dispatch (B-MOR on the 8 virtual devices).
    enc = BrainEncoder().fit(X[tr], Y[tr])
    r = enc.score(X[te], Y[te])
    m = np.asarray(responsive)
    d = enc.report_.decision
    print(f"[encode] dispatch: {d.solver} mesh={d.data_shards}x"
          f"{d.target_shards}")
    print(f"[encode] per-batch λ = {enc.report_.best_lambda}")
    print(f"[encode] test r — responsive {r[m].mean():.3f}, "
          f"non-responsive {r[~m].mean():.3f}")
    assert r[m].mean() > 0.3, "encoding failed to capture planted structure"
    print("OK: end-to-end backbone → B-MOR brain encoding succeeded.")


if __name__ == "__main__":
    main()
