"""Batched serving example: prefill a batch of prompts, then greedy-decode
continuations with the ring/full KV caches — the `serve_step` exercised by
the decode_32k / long_500k dry-run shapes, at CPU scale.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch gemma2-2b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.synthetic import make_batch
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.smoke(configs.get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name}: pattern={cfg.pattern}, window={cfg.window}")

    batch = make_batch(jax.random.PRNGKey(1), cfg, args.batch,
                       args.prompt_len, kind="prefill")
    logits, cache = jax.jit(model.prefill)(params, batch)
    print(f"prefill ok: logits {logits.shape}")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    start = args.prompt_len if cfg.family != "audio" else 1
    t0, n = time.time(), 0
    seqs = [tok]
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(start + i))
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        seqs.append(tok)
        n += args.batch
    dt = time.time() - t0
    out = jnp.concatenate(seqs, axis=1)
    print(f"decoded {out.shape[1]} tokens/req × {args.batch} reqs "
          f"→ {n/max(dt,1e-9):.1f} tok/s on CPU")
    for b in range(min(2, args.batch)):
        print(f"  req{b}: {out[b, :10].tolist()} ...")


if __name__ == "__main__":
    main()
