"""Serving-path benchmark: wave-batched encoder prediction latency.

Builds a small fleet of ``EncoderBundle``\\ s (fit once), registers them in
an ``EncoderRegistry``, and drives an ``EncoderService`` with synthetic
request traffic:

* **Wave sweep** — for each wave size, serve batches of ragged concurrent
  requests across all registry entries and record per-``serve()`` p50/p99
  latency, waves/s, and rows/s, plus the roofline placement of one wave
  (``launch.roofline_report.predict_roofline``, achieved FLOP/s from the
  p50).  The first call per wave size is the cold (compiling) call,
  reported separately.
* **Bucketed sweep** — the same traffic through a ``wave_buckets``
  service (2–3 ladder shapes picked per wave by rows remaining): records
  waves/rows/pad-fraction PER BUCKET plus the total pad fraction, the
  observable win over padding everything to one shape.
* **Registry timing** — cold bundle load (disk → device) vs warm LRU hit,
  and an eviction demo under a budget sized for 2 of the entries.
* **Compile-count assertion** — after the sweeps each service must have
  traced its predict EXACTLY once per distinct wave shape (all bundles
  share ``(p, t)``, so model count must NOT multiply compilations; the
  bucketed service once per bucket used).  The bench exits non-zero
  otherwise; the CI serving lane runs ``--smoke``.
* **Fault injection** (``--inject-faults``) — seeded transient faults on
  bundle loads must retry through (``FaultPolicy`` on virtual time)
  bit-identically with zero give-ups; a permanent one-model burst must
  give up into the typed per-request degradation leaving every other
  model's results untouched.  Retry/give-up counter deltas land in the
  ``fault_injection`` row.
* **Mixed-traffic trace replay** (``--replay-trace``) — the fleet tier's
  acceptance gate: the checked-in seeded trace
  (``benchmarks/traces/mixed_v1.json``: ragged rows, scored/unscored mix,
  tenants, Zipf-ish popularity over more models than the budget fits)
  replays through bounded admission + mixed waves, asserts BIT identity
  (predictions and Pearson r) against the per-request reference serve
  and compile_count == wave buckets used, and records flush p50/p99,
  rows/s, backpressure rejections, and per-tenant accounting.  The CI
  fleet lane runs ``--smoke --replay-trace``.

Writes ``BENCH_serving.json``::

    {"meta": {...}, "wave_sweep": [{"wave_rows", "cold_ms", "p50_ms",
      "p99_ms", "waves_per_s", "rows_per_s", "pad_fraction"}, ...],
     "bucketed": {"buckets", "per_bucket": {w: {"waves", "rows",
      "pad_rows", "pad_fraction"}}, "pad_fraction", "p50_ms",
      "rows_per_s", "compile_count"},
     "registry": {"entries", "resident_mb", "cold_load_ms", "warm_hit_ms",
      "eviction_demo": {...}},
     "mixed_traffic": {"trace", "digest", "p50_ms", "p99_ms", "rows_per_s",
      "rejections", "per_tenant": {...}, "bit_identical": true, ...},
     "compile_count": K, "distinct_wave_shapes": K}
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def sweep_wave(service, models: list[str], p: int, wave_rows: int,
               batches: int, reqs_per_batch: int, seed: int) -> dict:
    import numpy as np
    from repro.serving_encoders.traffic import ragged_requests

    rng = np.random.default_rng(seed)

    def make_batch():
        return ragged_requests(rng, models, p, wave_rows, reqs_per_batch)

    t0 = time.perf_counter()
    service.serve(make_batch(), wave_rows=wave_rows)      # cold: compiles
    cold_ms = (time.perf_counter() - t0) * 1e3

    walls = []
    waves0, rows0 = service.stats.waves, service.stats.rows
    pad0 = service.stats.pad_rows
    t_all = time.perf_counter()
    for _ in range(batches):
        batch = make_batch()
        t0 = time.perf_counter()
        service.serve(batch, wave_rows=wave_rows)
        walls.append((time.perf_counter() - t0) * 1e3)
    span = time.perf_counter() - t_all
    waves = service.stats.waves - waves0
    rows = service.stats.rows - rows0
    pad = service.stats.pad_rows - pad0
    return {
        "wave_rows": wave_rows,
        "batches": batches,
        "requests_per_batch": reqs_per_batch,
        "cold_ms": round(cold_ms, 3),
        "p50_ms": round(float(np.percentile(walls, 50)), 3),
        "p99_ms": round(float(np.percentile(walls, 99)), 3),
        "waves": waves,
        "waves_per_s": round(waves / span, 1),
        "rows_per_s": round(rows / span, 1),
        "pad_fraction": round(pad / max(rows + pad, 1), 4),
    }


def sweep_bucketed(registry, models: list[str], p: int,
                   buckets: tuple[int, ...], batches: int,
                   reqs_per_batch: int, seed: int) -> dict:
    """The mixed ragged traffic through a bucketed service: per-bucket
    wave/pad accounting + one-compile-per-bucket assertion."""
    import numpy as np
    from repro.serving_encoders import EncoderService
    from repro.serving_encoders.traffic import ragged_requests

    service = EncoderService(registry, wave_buckets=buckets)
    rng = np.random.default_rng(seed)
    service.serve(ragged_requests(rng, models, p, buckets[-1],
                                  reqs_per_batch))       # cold: compiles
    # Delta accounting around the timed loop (like sweep_wave): the cold
    # warm-up batch must not leak into the recorded pad economics.
    walls = []
    rows0, pad0 = service.stats.rows, service.stats.pad_rows
    bucket0 = {w: dict(b) for w, b in service.stats.per_bucket.items()}
    t_all = time.perf_counter()
    for _ in range(batches):
        batch = ragged_requests(rng, models, p, buckets[-1],
                                reqs_per_batch)
        t0 = time.perf_counter()
        service.serve(batch)
        walls.append((time.perf_counter() - t0) * 1e3)
    span = time.perf_counter() - t_all
    per_bucket = {}
    for w, b in sorted(service.stats.per_bucket.items()):
        base = bucket0.get(w, {"waves": 0, "rows": 0, "pad_rows": 0})
        d = {k: b[k] - base[k] for k in ("waves", "rows", "pad_rows")}
        per_bucket[str(w)] = {
            **d, "pad_fraction": round(
                d["pad_rows"] / max(d["rows"] + d["pad_rows"], 1), 4)}
    used = len(service.stats.per_bucket)
    if service.compile_count != used:
        print(f"FAIL: bucketed compile_count={service.compile_count} != "
              f"{used} buckets used")
        raise SystemExit(1)
    rows = service.stats.rows - rows0
    pad = service.stats.pad_rows - pad0
    return {
        "buckets": list(buckets),
        "per_bucket": per_bucket,
        "pad_fraction": round(pad / max(rows + pad, 1), 4),
        "p50_ms": round(float(np.percentile(walls, 50)), 3),
        "rows_per_s": round(rows / span, 1),
        "compile_count": service.compile_count,
    }


def time_registry(paths: list[str], wave_rows: int) -> dict:
    from repro.serving_encoders import EncoderRegistry
    from repro.serving_encoders.registry import bundle_resident_bytes
    from repro.serving_encoders.bundle import EncoderBundle

    reg = EncoderRegistry(wave_rows=wave_rows)
    cold, warm = [], []
    for i, path in enumerate(paths):
        name = f"m{i}"
        reg.add(name, path)
        t0 = time.perf_counter()
        reg.get(name)
        cold.append((time.perf_counter() - t0) * 1e3)
        t0 = time.perf_counter()
        reg.get(name)
        warm.append((time.perf_counter() - t0) * 1e3)
    # Eviction demo: budget for exactly 2 of the (identically sized)
    # bundles → cycling through all of them must evict.
    need = bundle_resident_bytes(EncoderBundle.open(paths[0]), wave_rows)
    reg2 = EncoderRegistry(device_memory_budget=int(2.5 * need),
                           wave_rows=wave_rows)
    for i, path in enumerate(paths):
        reg2.add(f"m{i}", path)
    for i in range(len(paths)):
        reg2.get(f"m{i}")
    assert reg2.evictions >= len(paths) - 2, reg2.stats()
    assert len(reg2.loaded_names) <= 2, reg2.loaded_names
    return {
        "entries": len(paths),
        "resident_mb": round(reg.resident_bytes / 2**20, 3),
        "cold_load_ms": [round(c, 3) for c in cold],
        "warm_hit_ms": [round(w, 4) for w in warm],
        "eviction_demo": {"budget_entries": 2, **reg2.stats()},
    }


def replay_mixed_trace(trace_path: str, workdir: str, *,
                       buckets: tuple[int, ...], n_fit: int,
                       budget_models: float, score_slots: int) -> dict:
    """Replay the checked-in mixed-traffic trace through the fleet tier.

    One deterministic workload — ragged rows, scored/unscored mix,
    multiple tenants, Zipf-ish popularity over MORE models than the
    registry budget fits — drives bounded admission (``FleetFrontend``)
    over a mixed-wave service, and the run is gated on the fleet tier's
    two contracts before any timing is reported:

    * **bit identity** — every packed prediction AND Pearson r must equal
      (``np.array_equal``) the per-request reference serve;
    * **compile economy** — ``compile_count`` == the number of wave
      buckets actually used, regardless of traffic mix.

    Returns the ``mixed_traffic`` payload row: flush p50/p99, rows/s,
    backpressure rejections, per-tenant accounting, registry churn.
    """
    import numpy as np
    from repro.serving_encoders import (EncoderRegistry, EncoderService,
                                        FleetFrontend, reference_serve)
    from repro.serving_encoders.bundle import EncoderBundle
    from repro.serving_encoders.registry import bundle_resident_bytes
    from repro.serving_encoders.traffic import (build_synthetic_fleet,
                                                load_trace, replay_requests)

    spec = load_trace(trace_path)
    fleet = build_synthetic_fleet(os.path.join(workdir, "trace_fleet"),
                                  spec.n_models, n=n_fit, p=spec.p,
                                  t=spec.t, provenance={"bench": "trace"})
    models = [name for name, _ in fleet]
    need = bundle_resident_bytes(EncoderBundle.open(fleet[0][1]),
                                 buckets[-1], None, score_slots)
    registry = EncoderRegistry(
        device_memory_budget=int(budget_models * need),
        wave_rows=buckets[0])
    for name, path in fleet:
        registry.add(name, path)
    service = EncoderService(registry, wave_buckets=buckets,
                             score_slots=score_slots, prefetch_next=True)
    frontend = FleetFrontend(service,
                             max_pending_rows=8 * buckets[-1])
    requests = replay_requests(spec, models)

    # Reference FIRST (its own registry/service so nothing is shared):
    # each request alone — what the packed serve must bit-match.
    ref_reg = EncoderRegistry(wave_rows=buckets[0])
    for name, path in fleet:
        ref_reg.add(name, path)
    ref_svc = EncoderService(ref_reg, wave_buckets=buckets,
                             score_slots=score_slots)
    reference = reference_serve(ref_svc, requests)

    # Replay under bounded admission, timing each flush (the SLO unit:
    # a flush drains everything the window admitted).
    results = [None] * len(requests)
    window, walls, rejections = [], [], 0
    rows_served = 0
    t_all = time.perf_counter()

    def flush():
        nonlocal rows_served
        if not window:
            return
        t0 = time.perf_counter()
        out = frontend.flush()
        walls.append((time.perf_counter() - t0) * 1e3)
        for i, res in zip(window, out):
            results[i] = res
        rows_served += sum(requests[i].features.shape[0] for i in window)
        window.clear()

    from repro.serving_encoders import ServiceError
    for i, req in enumerate(requests):
        try:
            frontend.submit(req)
            window.append(i)
        except ServiceError:
            rejections += 1
            flush()
            frontend.submit(req)               # window now empty: admits
            window.append(i)
    flush()
    span = time.perf_counter() - t_all

    mismatches = []
    for i, (got, want) in enumerate(zip(results, reference)):
        if got.error is not None or want.error is not None:
            mismatches.append((i, "unexpected fault"))
            continue
        if not np.array_equal(got.predictions, want.predictions):
            mismatches.append((i, "predictions"))
        if (got.pearson_r is None) != (want.pearson_r is None) or (
                got.pearson_r is not None
                and not np.array_equal(got.pearson_r, want.pearson_r)):
            mismatches.append((i, "pearson_r"))
    if mismatches:
        print(f"FAIL: packed mixed-wave serve diverges from the "
              f"per-request reference at {mismatches[:5]} "
              f"({len(mismatches)} total)")
        raise SystemExit(1)
    used = len(service.stats.per_bucket)
    if service.compile_count != used:
        print(f"FAIL: mixed-trace compile_count={service.compile_count} "
              f"!= {used} wave buckets used")
        raise SystemExit(1)
    print(f"trace replay: {len(requests)} requests bit-identical to the "
          f"per-request reference ✓ ({service.compile_count} compiles == "
          f"{used} buckets)")
    scored = sum(1 for e in spec.entries if e.scored)
    return {
        "trace": os.path.relpath(trace_path, REPO),
        "digest": spec.digest()[:16],
        "requests": len(requests),
        "scored_requests": scored,
        "tenants": len(service.stats.per_tenant),
        "models": spec.n_models,
        "budget_models": budget_models,
        "flushes": len(walls),
        "rejections": rejections,
        "p50_ms": round(float(np.percentile(walls, 50)), 3),
        "p99_ms": round(float(np.percentile(walls, 99)), 3),
        "rows_per_s": round(rows_served / span, 1),
        "pad_fraction": round(
            service.stats.pad_rows
            / max(service.stats.rows + service.stats.pad_rows, 1), 4),
        "per_tenant": {k: dict(v) for k, v in
                       sorted(service.stats.per_tenant.items())},
        "service_stats": service.stats.to_dict(),
        "compile_count": service.compile_count,
        "registry": {k: registry.stats()[k]
                     for k in ("loads", "evictions", "hits",
                               "peak_resident_bytes")},
        "bit_identical": True,
    }


def fault_injection_row(fleet, p: int, wave_rows: int, *, seed: int) -> dict:
    """Serve one deterministic batch three ways — clean, with injected
    transient bundle-load faults (must retry through bit-identically),
    and with a permanent fault burst (must give up into the typed
    per-request degradation) — and record the retry/give-up economics.

    The injector is seeded and the retry policy runs on virtual time
    (``FaultPolicy.with_virtual_time``), so the row is exactly
    reproducible: no sleeps, no wall-clock dependence.
    """
    import numpy as np
    from repro import obs
    from repro.resilience.faultsim import FaultInjector, flaky_bundle
    from repro.resilience.policy import FaultPolicy
    from repro.serving_encoders import EncoderRegistry, EncoderService
    from repro.serving_encoders.traffic import ragged_requests

    models = [name for name, _ in fleet]

    def build(policy=None, injector=None, only=None):
        reg = EncoderRegistry(wave_rows=wave_rows, fault_policy=policy)
        for name, path in fleet:
            reg.add(name, path)
            if injector is not None and (only is None or name in only):
                reg._bundles[name] = flaky_bundle(reg._bundles[name],
                                                  injector)
        return EncoderService(reg, wave_rows=wave_rows)

    def counter_deltas(before, ops=("io_retries", "io_giveups")):
        after = obs.snapshot()["counters"]
        return {op: sum(v - before.get(k, 0) for k, v in after.items()
                        if k.startswith(op)) for op in ops}

    reqs = ragged_requests(np.random.default_rng(seed), models, p,
                           wave_rows, 8)
    clean = build().serve(reqs, wave_rows=wave_rows)

    # Transient burst: the first load fails once, a later one twice —
    # both inside max_attempts, so every request must come back
    # bit-identical to the clean serve with zero give-ups.
    inj = FaultInjector(seed=11)
    inj.plan("bundle.load_encoder", 1)
    inj.plan("bundle.load_encoder", 4, times=2)
    policy = FaultPolicy(max_attempts=3, seed=11).with_virtual_time()
    before = dict(obs.snapshot()["counters"])
    faulty = build(policy, inj).serve(reqs, wave_rows=wave_rows)
    transient = counter_deltas(before)
    for i, (got, want) in enumerate(zip(faulty, clean)):
        if got.error is not None or want.error is not None or \
                not np.array_equal(got.predictions, want.predictions):
            print(f"FAIL: request {i} diverged under injected transient "
                  f"faults")
            raise SystemExit(1)
    if transient["io_retries"] < 3 or transient["io_giveups"]:
        print(f"FAIL: transient-fault serve recorded {transient} "
              f"(expected >=3 retries, 0 give-ups)")
        raise SystemExit(1)

    # Permanent burst: ONE model's loads fail past max_attempts — the
    # registry gives up into a typed BundleError and the service degrades
    # only that model's requests; everything else stays bit-identical.
    dead_model = reqs[0].model
    inj2 = FaultInjector(seed=12)
    inj2.plan("bundle.load_encoder", 1, times=99)
    before = dict(obs.snapshot()["counters"])
    degraded = build(policy, inj2, only={dead_model}).serve(
        reqs, wave_rows=wave_rows)
    permanent = counter_deltas(before)
    faults = survivors = 0
    for req, got, want in zip(reqs, degraded, clean):
        if req.model == dead_model:
            faults += 1
            if got.error is None:
                print(f"FAIL: {dead_model} request served despite a "
                      f"permanent load fault")
                raise SystemExit(1)
        else:
            survivors += 1
            if got.error is not None or \
                    not np.array_equal(got.predictions, want.predictions):
                print(f"FAIL: {req.model} degraded alongside {dead_model}")
                raise SystemExit(1)
    if permanent["io_giveups"] < 1:
        print(f"FAIL: permanent burst recorded no give-up: {permanent}")
        raise SystemExit(1)
    print(f"fault injection: {transient['io_retries']} retries "
          f"bit-identical, permanent burst degraded {faults} "
          f"request(s) of {dead_model} ({survivors} unaffected) ✓")
    return {"requests": len(reqs), "wave_rows": wave_rows,
            "transient": transient, "bit_identical": True,
            "permanent": permanent, "degraded_model": dead_model,
            "degraded_requests": faults, "unaffected_requests": survivors}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + fewer batches (CI serving lane)")
    ap.add_argument("--out", default=None,
                    help="output JSON (default: BENCH_serving.json at the "
                         "repo root; --smoke defaults to workdir)")
    ap.add_argument("--workdir", default=None,
                    help="bundle fleet directory (default: a tempdir)")
    ap.add_argument("--models", type=int, default=3,
                    help="registry entries (acceptance floor: 3)")
    ap.add_argument("--replay-trace", nargs="?", default=None,
                    const=os.path.join(REPO, "benchmarks", "traces",
                                       "mixed_v1.json"),
                    help="replay this mixed-traffic trace through the "
                         "fleet tier (default file when given bare); "
                         "gates bit-identity vs the per-request "
                         "reference and writes the mixed_traffic "
                         "p50/p99 rows")
    ap.add_argument("--inject-faults", action="store_true",
                    help="seeded transient/permanent fault injection on "
                         "bundle loads: gates retry bit-identity + typed "
                         "give-up degradation, writes the fault_injection "
                         "row")
    args = ap.parse_args()

    if args.smoke:
        n, p, t = 256, 64, 96
        wave_sizes = (16, 32)
        buckets = (8, 32)
        batches, reqs = 5, 4
    else:
        n, p, t = 2048, 128, 512
        wave_sizes = (32, 64, 128)
        buckets = (32, 128)
        batches, reqs = 30, 8
    workdir = args.workdir or tempfile.mkdtemp(prefix="serving_bench_")
    os.makedirs(workdir, exist_ok=True)
    out = args.out or (os.path.join(workdir, "BENCH_serving.json")
                       if args.smoke
                       else os.path.join(REPO, "BENCH_serving.json"))

    import jax
    from repro.serving_encoders import EncoderRegistry, EncoderService
    from repro.serving_encoders.traffic import build_synthetic_fleet

    t0 = time.perf_counter()
    fleet = build_synthetic_fleet(workdir, args.models, n=n, p=p, t=t,
                                  provenance={"bench": "serving"})
    paths = [path for _, path in fleet]
    fit_s = time.perf_counter() - t0
    print(f"fleet of {len(paths)} bundles ready in {fit_s:.1f}s "
          f"({workdir})")

    registry = EncoderRegistry(wave_rows=max(wave_sizes))
    models = []
    for name, path in fleet:
        registry.add(name, path)
        models.append(name)
    service = EncoderService(registry, wave_rows=wave_sizes[0])

    from repro.launch.roofline_report import predict_roofline

    sweep = []
    for w in wave_sizes:
        row = sweep_wave(service, models, p, w, batches, reqs, seed=w)
        # Roofline placement of one wave (Ŷ = X·W at this wave shape),
        # achieved FLOP/s from the measured p50 — informational only.
        row["roofline"] = predict_roofline(w, p, t,
                                           wall_s=row["p50_ms"] * 1e-3)
        sweep.append(row)
        print(f"wave_rows={w:4d}: cold {row['cold_ms']:.1f} ms, "
              f"p50 {row['p50_ms']:.2f} ms, p99 {row['p99_ms']:.2f} ms, "
              f"{row['waves_per_s']:.0f} waves/s, "
              f"{row['rows_per_s']:.0f} rows/s, "
              f"{row['roofline']['bottleneck']}-bound")

    # THE acceptance assertion: one compiled predict per distinct wave
    # shape — model count and request traffic must not multiply traces.
    distinct = len(wave_sizes)
    if service.compile_count != distinct:
        print(f"FAIL: compile_count={service.compile_count} != "
              f"{distinct} distinct wave shapes")
        raise SystemExit(1)
    print(f"compiled predicts: {service.compile_count} "
          f"== {distinct} distinct wave shapes ✓")

    bucketed = sweep_bucketed(registry, models, p, buckets, batches, reqs,
                              seed=1234)
    print(f"bucketed {buckets}: pad fraction {bucketed['pad_fraction']} "
          f"(per bucket: "
          + ", ".join(f"{w}→{b['pad_fraction']}"
                      for w, b in bucketed["per_bucket"].items())
          + f"), {bucketed['compile_count']} compiles ✓")

    reg_stats = time_registry(paths, max(wave_sizes))
    injected = None
    if args.inject_faults:
        injected = fault_injection_row(fleet, p, wave_sizes[0], seed=99)
    mixed = None
    if args.replay_trace:
        mixed = replay_mixed_trace(
            args.replay_trace, workdir, buckets=buckets,
            n_fit=min(n, 256), budget_models=2.5, score_slots=4)
        print(f"mixed traffic [{mixed['trace']}]: "
              f"p50 {mixed['p50_ms']:.2f} ms, p99 {mixed['p99_ms']:.2f} ms, "
              f"{mixed['rows_per_s']:.0f} rows/s, "
              f"{mixed['rejections']} backpressure rejections, "
              f"{mixed['registry']['evictions']} evictions over "
              f"{mixed['models']} models")
    payload = {
        "meta": {"n_fit": n, "p": p, "t": t, "models": len(paths),
                 "device": jax.devices()[0].platform,
                 "device_count": jax.device_count(),
                 "smoke": bool(args.smoke), "fit_seconds": round(fit_s, 2)},
        "wave_sweep": sweep,
        "bucketed": bucketed,
        "registry": reg_stats,
        "service_stats": service.stats.to_dict(),
        "compile_count": service.compile_count,
        "distinct_wave_shapes": distinct,
    }
    # Process-global obs metrics snapshot (waves/rows/tenant counters the
    # instrumented service publishes) rides along for downstream tooling.
    from repro import obs
    payload["metrics"] = obs.snapshot()
    if injected is not None:
        payload["fault_injection"] = injected
    if mixed is not None:
        payload["mixed_traffic"] = mixed
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
