"""Out-of-core trajectory: in-memory vs streamed vs sharded-streamed fits.

Each variant runs in its OWN subprocess so its peak RSS
(``getrusage(RUSAGE_SELF).ru_maxrss``) is an honest per-variant high-water
mark, not polluted by a predecessor's allocations:

* ``inmem``         — ``RunStore.load()`` then the ordinary materialised
  ``BrainEncoder.fit(X, Y)`` (the λ reference; holds ``(n, p)+(n, t)``).
* ``streamed``      — ``fit(store=...)`` under a 1-byte memory budget:
  dispatch pins ``method="chunked"``, the rows stream from the
  memory-mapped shards through the double-buffered prefetch reader, and
  every chunk goes through the ONE fixed-shape compiled masked update;
  resident set is one chunk + staging buffers + ``(k, p, p+t)`` stats.
* ``streamed_nopf`` — the same with prefetch OFF (serial read→accumulate):
  the overlap A/B.  λ and weights are bit-identical to ``streamed``.
* ``sharded``       — prefetched streaming with the accumulation sharded
  over 8 virtual CPU devices (``shard_row_ranges``, single psum finalize).

Every streamed child HARD-ASSERTS the accumulation's trace-time compile
count is exactly 1 (deterministic — the fixed-shape contract) and reports
the reader-stall vs compute-stall breakdown.  The parent asserts λ
selection is bit-identical across all variants, derives the
streamed/in-memory wall ratio + the prefetch overlap gain, and writes
``BENCH_oocore.json``::

    {"rss_cap_mb": ..., "rows": [{"name", "n", "p", "t",
      "array_mb",              # n·(p+t)·4 — what in-memory must hold
      "inmem": {"wall_s", "peak_rss_mb", "best_lambda", "roofline"},
      "streamed": {..., "read_stall_s", "compute_stall_s", "bytes_staged",
                   "compile_count", "roofline"},
      "streamed_nopf": {...}, "sharded": {...},
      "streamed_over_inmem": W_s/W_i, "overlap_gain": W_nopf/W_s,
      "lambda_match": true, "streamed_under_cap": true}, ...]}

Each variant also carries a ``roofline`` placement
(``repro.launch.roofline_report.encoding_roofline``): achieved FLOP/byte
against the host envelope (``--peak-gflops``/``--mem-bw-gbs``), with
bytes = the actually staged traffic for the streaming variants —
reported, never gated.

``--smoke`` runs one small shape (CI parity guard; prints the overlap
ratios — reported, not gated, CPU wall times are load-sensitive).
``--streamed-only`` runs just the streaming variants on the tall shape —
the mode the CI memory-capped lane executes under a ulimit the in-memory
path could not survive — and fails if the streamed peak RSS exceeds
``--rss-cap-mb`` or if the in-memory array bytes do NOT exceed the cap
(i.e. the cap would not have proven anything).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

# (name, n, p, t, chunk_rows).  ``tall`` is sized so its arrays alone
# (n·(p+t)·4 B ≈ 1.2 GB) exceed the CI lane's 1 GiB RSS cap; its chunk
# size keeps even the 8-virtual-device sharded variant (8 device
# allocator arenas, one in-flight chunk each) under that cap.
SHAPES = [
    ("medium", 400_000, 64, 96, 32_768),
    ("tall", 1_200_000, 96, 160, 16_384),
]
SMOKE_SHAPES = [("smoke", 60_000, 32, 48, 8_192)]


def _ensure_store(path: str, n: int, p: int, t: int) -> None:
    from repro.data import fmri
    from repro.data.store import MANIFEST_NAME, RunStore

    if os.path.exists(os.path.join(path, MANIFEST_NAME)):
        return
    spec = fmri.SubjectSpec(n=n, p=p, t=t)
    RunStore.create(path).materialize_synthetic(spec, rows_per_run=65_536)


def run_variant(variant: str, store_path: str, n_folds: int,
                chunk_rows: int) -> dict:
    """Child entry: one fit, one JSON result line on stdout."""
    import resource

    import numpy as np
    from repro.data.store import RunStore
    from repro.encoding import BrainEncoder

    store = RunStore.open(store_path)
    t0 = time.time()
    stream = None
    if variant == "inmem":
        X, Y = store.load()
        enc = BrainEncoder(solver="ridge", method="eigh",
                           n_folds=n_folds).fit(X, Y)
    else:
        import jax
        data_shards = jax.device_count() if variant == "sharded" else 1
        enc = BrainEncoder(n_folds=n_folds, device_memory_budget=1,
                           chunk_rows=chunk_rows, data_shards=data_shards,
                           prefetch=variant != "streamed_nopf"
                           ).fit(store=store)
        assert enc.report_.decision.method == "chunked"
        stream = enc.stream_stats_
        # THE deterministic gate: the whole chunked accumulation traces
        # exactly once, whatever the chunk/fold alignment (fresh process,
        # so the count is absolute, not a delta).
        if stream["compile_count"] != 1:
            raise SystemExit(
                f"{variant}: accumulation compiled "
                f"{stream['compile_count']}× (fixed-shape contract is 1)")
    np.asarray(enc.weights_)                      # force materialisation
    wall = time.time() - t0
    peak_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    res = {"variant": variant, "wall_s": round(wall, 2),
           "peak_rss_mb": round(peak_kb / 1024, 1),
           "best_lambda": float(enc.report_.best_lambda[0])}
    if stream is not None:
        res.update(
            read_stall_s=round(stream["read_stall_s"], 2),
            compute_stall_s=round(stream["compute_stall_s"], 2),
            bytes_staged=int(stream["bytes_staged"]),
            compile_count=stream["compile_count"],
            stream_stats=dict(stream))       # full schema'd dict rides along
    # Per-child obs metrics snapshot (the counters the instrumented fit
    # published in THIS process — each variant is its own process, so the
    # numbers are per-variant, not cumulative).
    from repro import obs
    res["metrics"] = obs.snapshot()
    return res


def spawn_variant(variant: str, store_path: str, n_folds: int,
                  chunk_rows: int) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    if variant == "sharded":
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--variant", variant,
         "--store", store_path, "--n-folds", str(n_folds),
         "--chunk-rows", str(chunk_rows)],
        capture_output=True, text=True, env=env)
    if proc.returncode != 0:
        raise SystemExit(f"{variant} child failed:\n{proc.stdout}\n"
                         f"{proc.stderr}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("OOCORE_RESULT ")][-1]
    return json.loads(line[len("OOCORE_RESULT "):])


def bench_shape(name: str, n: int, p: int, t: int, chunk_rows: int,
                n_folds: int, workdir: str, variants: list[str],
                rss_cap_mb: float, peak_flops: float,
                mem_bw: float) -> dict:
    store_path = os.path.join(workdir, f"{name}_{n}x{p}x{t}")
    print(f"[{name}] materialising store at {store_path} ...", flush=True)
    _ensure_store(store_path, n, p, t)
    row: dict = {"name": name, "n": n, "p": p, "t": t,
                 "chunk_rows": chunk_rows,
                 "array_mb": round(n * (p + t) * 4 / 2**20, 1)}
    from repro.launch.roofline_report import encoding_roofline
    for variant in variants:
        res = spawn_variant(variant, store_path, n_folds, chunk_rows)
        row[variant] = {k: v for k, v in res.items() if k != "variant"}
        # Roofline placement (reported, never gated): achieved FLOP/byte
        # against the host envelope, bytes = actual staged traffic for the
        # streaming variants, nominal array bytes for in-memory.
        roof = encoding_roofline(
            n, p, t, n_folds=n_folds, wall_s=res["wall_s"],
            bytes_staged=res.get("bytes_staged"),
            peak_flops=peak_flops, mem_bw=mem_bw)
        row[variant]["roofline"] = {
            "flop_per_byte": round(roof["flop_per_byte"], 2),
            "peak_flop_per_byte": round(roof["peak_flop_per_byte"], 2),
            "peak_fraction": round(roof["peak_fraction"], 4),
            "bottleneck": roof["bottleneck"]}
        extra = ""
        if "read_stall_s" in res:
            extra = (f" read_stall={res['read_stall_s']}s "
                     f"compute_stall={res['compute_stall_s']}s "
                     f"compiles={res['compile_count']}")
        extra += (f" roofline={roof['flop_per_byte']:.1f}/"
                  f"{roof['peak_flop_per_byte']:.1f} FLOP/B "
                  f"({roof['peak_fraction'] * 100:.1f}% of peak, "
                  f"{roof['bottleneck']}-bound)")
        print(f"[{name}] {variant}: {res['wall_s']}s "
              f"rss={res['peak_rss_mb']}MB λ={res['best_lambda']}{extra}",
              flush=True)
    lams = {row[v]["best_lambda"] for v in variants}
    row["lambda_match"] = len(lams) == 1
    if not row["lambda_match"]:
        raise SystemExit(f"λ selection diverged on {name}: {lams}")
    if "inmem" in row and "streamed" in row:
        row["streamed_over_inmem"] = round(
            row["streamed"]["wall_s"] / max(row["inmem"]["wall_s"], 1e-9), 3)
    if "streamed_nopf" in row and "streamed" in row:
        row["overlap_gain"] = round(
            row["streamed_nopf"]["wall_s"]
            / max(row["streamed"]["wall_s"], 1e-9), 3)
    streamed = [v for v in variants if v != "inmem"]
    row["streamed_under_cap"] = all(
        row[v]["peak_rss_mb"] < rss_cap_mb for v in streamed)
    return row


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--variant", default=None,
                    help="(internal) child mode: inmem|streamed|sharded")
    ap.add_argument("--store", default=None, help="(internal) child store")
    ap.add_argument("--chunk-rows", type=int, default=8192,
                    help="(internal) child streaming chunk size")
    ap.add_argument("--n-folds", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="one small shape — CI parity guard")
    ap.add_argument("--streamed-only", action="store_true",
                    help="skip the in-memory variant (memory-capped CI "
                         "lane: the cap would kill it) and enforce the cap")
    ap.add_argument("--rss-cap-mb", type=float, default=1024.0,
                    help="RSS ceiling the streamed variants must stay under")
    ap.add_argument("--peak-gflops", type=float, default=None,
                    help="host peak GFLOP/s for the roofline placement "
                         "(reported, never gated)")
    ap.add_argument("--mem-bw-gbs", type=float, default=None,
                    help="host staging bandwidth GB/s for the roofline "
                         "placement (reported, never gated)")
    ap.add_argument("--workdir", default=None,
                    help="store directory (default: a temp dir)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.variant:                               # child mode
        res = run_variant(args.variant, args.store, args.n_folds,
                          args.chunk_rows)
        print("OOCORE_RESULT " + json.dumps(res), flush=True)
        return

    if args.out is None:
        args.out = os.path.join(
            REPO, "BENCH_oocore_smoke.json" if args.smoke
            else "BENCH_oocore.json")
    shapes = SMOKE_SHAPES if args.smoke else SHAPES
    variants = (["streamed", "sharded"] if args.streamed_only
                else ["inmem", "streamed", "streamed_nopf", "sharded"])
    workdir = args.workdir or tempfile.mkdtemp(prefix="oocore_bench_")

    from repro.launch.roofline_report import CPU_MEM_BW, CPU_PEAK_FLOPS
    peak_flops = (args.peak_gflops * 1e9 if args.peak_gflops
                  else CPU_PEAK_FLOPS)
    mem_bw = args.mem_bw_gbs * 1e9 if args.mem_bw_gbs else CPU_MEM_BW

    rows = []
    for name, n, p, t, chunk_rows in shapes:
        if args.streamed_only and name not in ("tall", "smoke"):
            continue
        rows.append(bench_shape(name, n, p, t, chunk_rows, args.n_folds,
                                workdir, variants, args.rss_cap_mb,
                                peak_flops, mem_bw))

    for row in rows:
        if "streamed_over_inmem" in row:
            # Reported, not gated: CPU wall times are load-sensitive; the
            # deterministic gates are λ parity + compile_count == 1 above.
            print(f"# [{row['name']}] streamed/inmem wall = "
                  f"{row['streamed_over_inmem']}x, prefetch overlap gain "
                  f"(no-prefetch/prefetch) = "
                  f"{row.get('overlap_gain', 'n/a')}x")

    if args.streamed_only:
        for row in rows:
            if not row["streamed_under_cap"]:
                raise SystemExit(
                    f"streamed path exceeded the {args.rss_cap_mb} MB cap: "
                    f"{row}")
            if not args.smoke and row["array_mb"] <= args.rss_cap_mb:
                raise SystemExit(
                    f"cap {args.rss_cap_mb} MB does not bind: in-memory "
                    f"arrays are only {row['array_mb']} MB — raise the "
                    f"shape or lower the cap")
        print(f"# streamed path bounded under {args.rss_cap_mb} MB RSS")

    payload = {"n_folds": args.n_folds, "smoke": args.smoke,
               "rss_cap_mb": args.rss_cap_mb,
               "roofline_envelope": {"peak_flops": peak_flops,
                                     "mem_bw": mem_bw},
               "rows": rows}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
