"""Distributed benchmark rows (fig8/9/10) — run by benchmarks.run in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8.

Every ridge/B-MOR row times the full ``BrainEncoder`` fit path — what a
user actually calls (mesh construction and data placement included); only
fig8's MOR row keeps the direct taskwise per-target dispatch that
reproduces the paper's Dask cost semantics (``mor.mor_fit_taskwise`` is
Fig. 8's measurement protocol, not a convenience wrapper).
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import complexity, mor, ridge
from repro.encoding import BrainEncoder


def timed(fn, reps=3):
    jax.block_until_ready(fn())
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.time() - t0) / reps * 1e6  # µs


def main():
    # p large enough that T_M (∝ p²n per factorisation) dominates dispatch
    # overhead on the virtual devices; otherwise the t·T_M vs c·T_M gap is
    # invisible at toy scale.
    n, p, t = 1024, 256, 512
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    X = jax.random.normal(k1, (n, p), jnp.float32)
    W = jax.random.normal(k2, (p, t), jnp.float32) / np.sqrt(p)
    Y = X @ W + 0.1 * jax.random.normal(k3, (n, t))
    cfg = ridge.RidgeCVConfig(n_folds=3)
    w = complexity.RidgeWorkload(n=n, p=p, t=t, r=len(cfg.lambdas),
                                 n_folds=cfg.n_folds)

    enc_single = BrainEncoder(solver="ridge", n_folds=cfg.n_folds)
    us_single = timed(lambda: enc_single.fit(X, Y).weights_, reps=2)

    # Virtual shards share ONE core: measured time ≈ total WORK; the ideal
    # wall-clock on real chips is work/c.  Rows report both.

    # fig8: MOR vs B-MOR at the same t and c — the t·T_M vs c·T_M overhead.
    # MOR runs TASKWISE (one isolated dispatch per target, as Dask does):
    # inside one XLA program the per-target factorisation is loop-invariant
    # and gets hoisted, which silently removes the redundancy the paper
    # measures (recorded finding — EXPERIMENTS §Paper-validation).
    c = 8
    t_small = 64
    Ys = Y[:, :t_small]
    jax.block_until_ready(mor.mor_fit_taskwise(X, Ys[:, :1], cfg))  # compile
    t0 = time.time()
    jax.block_until_ready(mor.mor_fit_taskwise(X, Ys, cfg))
    us_mor = (time.time() - t0) * 1e6
    enc8 = BrainEncoder(solver="bmor", data_shards=1, target_shards=c,
                        n_folds=cfg.n_folds)
    us_bmor_small = timed(lambda: enc8.fit(X, Ys).weights_, reps=2)
    w_small = complexity.RidgeWorkload(n=n, p=p, t=t_small,
                                       r=len(cfg.lambdas),
                                       n_folds=cfg.n_folds)
    model_work_ratio = (complexity.t_w(w_small) +
                        w_small.t * complexity.t_m(w_small)) / \
        (complexity.t_w(w_small) + c * complexity.t_m(w_small))
    print(f"fig8_mor_overhead,{us_mor:.1f},"
          f"bmor_same_t_us={us_bmor_small:.1f};"
          f"measured_work_ratio={us_mor/us_bmor_small:.1f};"
          f"model_work_ratio={model_work_ratio:.1f};t={t_small};c={c};"
          f"mor=taskwise")

    # fig9/10: B-MOR scaling across target shards (ideal wall = work/c) —
    # timed through the estimator facade (fit = place + bmor_fit + unpad).
    base_wall = None
    for c in (1, 2, 4, 8):
        enc = BrainEncoder(solver="bmor", data_shards=1, target_shards=c,
                           n_folds=cfg.n_folds)
        us = timed(lambda: enc.fit(X, Y).weights_, reps=2)
        wall = us / c
        base_wall = base_wall or wall
        model_scaling = complexity.t_bmor(w, 1) / complexity.t_bmor(w, c)
        print(f"fig9_bmor_scaling_c{c},{us:.1f},"
              f"ideal_wall_us={wall:.1f};speedup_vs_c1={base_wall/wall:.2f}")
        print(f"fig10_bmor_speedup_c{c},{wall:.1f},"
              f"scaling_measured={base_wall/wall:.2f};"
              f"scaling_model={model_scaling:.2f};"
              f"DSU_model_vs_single={complexity.predicted_speedup_bmor(w, c):.2f}")

    # dispatch sanity row: what solver="auto" would run at this shape, and
    # the dispatch overhead (resolution only — no fit).
    from repro.encoding import EncoderConfig, resolve
    t0 = time.time()
    decision = resolve(EncoderConfig(), n, p, t, jax.device_count())
    us_dispatch = (time.time() - t0) * 1e6
    print(f"dispatch_auto,{us_dispatch:.1f},"
          f"solver={decision.solver};layout={decision.data_shards}x"
          f"{decision.target_shards};single_ridge_us={us_single:.1f}")


if __name__ == "__main__":
    main()
