"""Reproduce the §Perf fleet-optimisation sweep (EXPERIMENTS.md).

Runs the winning lever for every misfit combo of the baseline dry-run and
appends records to results/fleet.jsonl.  Each entry is one
``repro.launch.perf`` invocation (subprocess: the dry-run needs its own
XLA_FLAGS before jax init).

Usage: PYTHONPATH=src python benchmarks/fleet_sweep.py [--only ARCH]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# (arch, shape, extra perf args, label) — levers per EXPERIMENTS §Perf.
SWEEP = [
    # decode → cache-in-carry (default) + tp_cacheseq when KV under-fills TP
    ("gemma-7b", "decode_32k", [], "carrycache"),
    ("gemma2-2b", "decode_32k", ["--rules", "tp_cacheseq"], "cacheseq"),
    ("gemma3-12b", "decode_32k", ["--rules", "tp_cacheseq"], "cacheseq"),
    ("qwen3-1.7b", "decode_32k", ["--rules", "tp_cacheseq"], "cacheseq"),
    ("phi3.5-moe-42b-a6.6b", "decode_32k", ["--rules", "tp_cacheseq"],
     "cacheseq"),
    ("llava-next-34b", "decode_32k",
     ["--pad-heads", "64", "--rules", "tp_cacheseq"], "pad64+cacheseq"),
    ("grok-1-314b", "decode_32k", ["--rules", "tp_cacheseq"], "cacheseq"),
    # prefill → flash attention (+ per-arch extras)
    ("gemma-7b", "prefill_32k", ["--flash", "8192"], "flash"),
    ("gemma2-2b", "prefill_32k", ["--flash", "8192"], "flash"),
    ("gemma3-12b", "prefill_32k", ["--flash", "8192"], "flash"),
    ("qwen3-1.7b", "prefill_32k", ["--flash", "8192"], "flash"),
    ("zamba2-2.7b", "prefill_32k", ["--flash", "8192"], "flash"),
    ("phi3.5-moe-42b-a6.6b", "prefill_32k", ["--flash", "8192"], "flash"),
    ("grok-1-314b", "prefill_32k", ["--flash", "8192"], "flash"),
    ("seamless-m4t-medium", "prefill_32k",
     ["--flash", "8192", "--pad-vocab", "256256"], "flash+padvocab"),
    ("llava-next-34b", "prefill_32k",
     ["--flash", "8192", "--pad-heads", "64", "--batch", "8"],
     "flash+pad64+wave8"),
    # train → microbatch depth; FSDP only when args (params+opt) dominate
    ("gemma-7b", "train_4k", ["--microbatch", "8"], "mb8"),
    ("gemma2-2b", "train_4k", ["--microbatch", "16"], "mb16"),
    ("gemma3-12b", "train_4k", ["--rules", "tp_fsdp", "--microbatch", "8"],
     "fsdp+mb8"),
    ("phi3.5-moe-42b-a6.6b", "train_4k",
     ["--rules", "tp_fsdp", "--microbatch", "8"], "fsdp+mb8"),
    ("llava-next-34b", "train_4k",
     ["--rules", "tp_fsdp", "--microbatch", "8", "--pad-heads", "64"],
     "fsdp+mb8+pad64"),
    ("grok-1-314b", "train_4k", ["--rules", "tp_fsdp", "--microbatch", "8"],
     "fsdp+mb8"),
    ("qwen3-1.7b", "train_4k", ["--flash", "4096"], "flash"),
    ("seamless-m4t-medium", "train_4k",
     ["--pad-vocab", "256256", "--flash", "4096"], "flash+padvocab"),
    # long_500k residuals
    ("llava-next-34b", "long_500k",
     ["--pad-heads", "64", "--rules", "tp_cacheseq"], "pad64+cacheseq"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default="results/fleet.jsonl")
    args = ap.parse_args()
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    failures = []
    for arch, shape, extra, label in SWEEP:
        if args.only and args.only != arch:
            continue
        cmd = [sys.executable, "-m", "repro.launch.perf", "--arch", arch,
               "--shape", shape, "--label", f"fleet:{label}",
               "--json", args.json, *extra]
        print(">>", " ".join(cmd), flush=True)
        p = subprocess.run(cmd, env=env, cwd=REPO)
        if p.returncode != 0:
            failures.append((arch, shape, label))
    print(f"done; {len(failures)} failures: {failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
