"""Fallback parser: rebuild dry-run JSONL records from a sweep log.

The dry-run prints every record; this recovers them if the process dies
before its final JSON flush (the launcher now appends incrementally, but
logs from older runs remain parseable).

Also accepts ``repro.obs`` JSONL span traces (``--trace-out`` output):
the file is sniffed per line, and span events are normalised to the same
record shape (one dict per line, ``kind: "span"``) so downstream tooling
can mix sweep logs and traces in one pass.
"""
from __future__ import annotations

import ast
import json
import re
import sys

HDR = re.compile(r"^== (\S+) × (\S+) × (\S+) \(rules=(\w+)\) ==")
MEM = re.compile(r"temp_size_in_bytes=(\d+)")
ARG = re.compile(r"argument_size_in_bytes=(\d+)")
COST = re.compile(r"flops=([\d.e+-]+) bytes=([\d.e+-]+)")
COLL = re.compile(r"^collective_bytes: (\{.*\})")


def _is_obs_trace(path: str) -> bool:
    """Sniff: first non-blank line is a JSON object with name + ts_us."""
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except ValueError:
                return False
            return isinstance(ev, dict) and "name" in ev and "ts_us" in ev
    return False


def parse_obs_trace(path: str) -> list[dict]:
    """Normalise a repro.obs JSONL span trace to sweep-record dicts."""
    records = []
    for line in open(path):
        line = line.strip()
        if not line:
            continue
        ev = json.loads(line)
        rec = {"kind": "instant" if ev.get("instant") else "span",
               "name": ev["name"], "ts_us": ev["ts_us"],
               "dur_us": ev.get("dur_us", 0.0),
               "track": ev.get("track"), "depth": ev.get("depth")}
        rec.update(ev.get("attrs") or {})
        records.append(rec)
    return records


def parse(path: str) -> list[dict]:
    if _is_obs_trace(path):
        return parse_obs_trace(path)
    records, cur = [], None
    for line in open(path):
        m = HDR.match(line)
        if m:
            if cur and "flops" in cur:
                records.append(cur)
            cur = {"arch": m.group(1), "shape": m.group(2),
                   "mesh": m.group(3), "rules": m.group(4)}
            continue
        if cur is None:
            continue
        if line.startswith("memory_analysis:"):
            t, a = MEM.search(line), ARG.search(line)
            cur["memory"] = {"temp_size_in_bytes": int(t.group(1)) if t else 0,
                             "argument_size_in_bytes":
                                 int(a.group(1)) if a else 0}
        elif line.startswith("cost_analysis"):
            m = COST.search(line)
            cur["flops"] = float(m.group(1))
            cur["hlo_bytes"] = float(m.group(2))
        else:
            m = COLL.match(line)
            if m:
                d = ast.literal_eval(m.group(1))
                cur["collective_bytes"] = {k: float(v) for k, v in d.items()}
    if cur and "flops" in cur:
        records.append(cur)
    return records


if __name__ == "__main__":
    recs = parse(sys.argv[1])
    out = sys.argv[2] if len(sys.argv) > 2 else "/dev/stdout"
    with open(out, "w") as f:
        for r in recs:
            f.write(json.dumps(r) + "\n")
    print(f"parsed {len(recs)} records", file=sys.stderr)
