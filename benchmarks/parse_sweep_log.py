"""Fallback parser: rebuild dry-run JSONL records from a sweep log.

The dry-run prints every record; this recovers them if the process dies
before its final JSON flush (the launcher now appends incrementally, but
logs from older runs remain parseable).
"""
from __future__ import annotations

import ast
import json
import re
import sys

HDR = re.compile(r"^== (\S+) × (\S+) × (\S+) \(rules=(\w+)\) ==")
MEM = re.compile(r"temp_size_in_bytes=(\d+)")
ARG = re.compile(r"argument_size_in_bytes=(\d+)")
COST = re.compile(r"flops=([\d.e+-]+) bytes=([\d.e+-]+)")
COLL = re.compile(r"^collective_bytes: (\{.*\})")


def parse(path: str) -> list[dict]:
    records, cur = [], None
    for line in open(path):
        m = HDR.match(line)
        if m:
            if cur and "flops" in cur:
                records.append(cur)
            cur = {"arch": m.group(1), "shape": m.group(2),
                   "mesh": m.group(3), "rules": m.group(4)}
            continue
        if cur is None:
            continue
        if line.startswith("memory_analysis:"):
            t, a = MEM.search(line), ARG.search(line)
            cur["memory"] = {"temp_size_in_bytes": int(t.group(1)) if t else 0,
                             "argument_size_in_bytes":
                                 int(a.group(1)) if a else 0}
        elif line.startswith("cost_analysis"):
            m = COST.search(line)
            cur["flops"] = float(m.group(1))
            cur["hlo_bytes"] = float(m.group(2))
        else:
            m = COLL.match(line)
            if m:
                d = ast.literal_eval(m.group(1))
                cur["collective_bytes"] = {k: float(v) for k, v in d.items()}
    if cur and "flops" in cur:
        records.append(cur)
    return records


if __name__ == "__main__":
    recs = parse(sys.argv[1])
    out = sys.argv[2] if len(sys.argv) > 2 else "/dev/stdout"
    with open(out, "w") as f:
        for r in recs:
            f.write(json.dumps(r) + "\n")
    print(f"parsed {len(recs)} records", file=sys.stderr)
