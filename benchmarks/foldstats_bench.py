"""Fold-statistics perf trajectory: seed per-fold CV vs single-pass downdating.

Times ``ridge.ridge_cv_reference`` (the seed path: every CV split
concatenates its training rows and re-accumulates their Gram — ``k·np²`` of
``T_W`` on the critical path) against ``ridge.ridge_cv`` (single-pass fold
statistics + exact Gram downdating, ``np²`` once) on the shapes used by
``benchmarks/run.py``/``distributed_bench.py``, for both factorisation
sides, and asserts bit-level λ agreement plus f32-tolerance weight parity
while it measures.

Writes ``BENCH_foldstats.json`` next to the repo root so the perf
trajectory is machine-readable::

    {"rows": [{"name", "n", "p", "t", "n_folds", "seed_us", "folded_us",
               "speedup", "lambda_match", "max_weight_err"}, ...]}

``--smoke`` runs one tiny shape with a single rep — a CI guard that the
perf path still imports and the two implementations still agree.

Streamed A/B rows (``*_streamed``) time the chunked fold-statistics
accumulation with the kernel tier off vs on (``use_pallas``), assert λ
bit-identity between the two, and carry the §3 roofline placement of the
fit (``launch.roofline_report.encoding_roofline``).  ``--use-pallas``
additionally requires the AUTO kernel-tier dispatch to engage (setting
``REPRO_PALLAS_FORCE_INTERPRET=1`` if unset) and exits non-zero on a
silent fallback — the CI pallas lane's guard.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# (name, n, p, t): shapes track benchmarks/run.py + distributed_bench
# problem sizes; dual is the n < p whole-brain-MOR-style regime.
SHAPES = [
    ("small", 512, 128, 256),
    ("fig4_encoding", 1080, 128, 512),     # run.py fig4's train split
    ("medium", 1024, 256, 512),            # distributed_bench.py's shape
    ("fig7_largest", 1024, 384, 1024),     # run.py fig7's largest row
    ("dual", 256, 1024, 256),
]
SMOKE_SHAPES = [("smoke", 96, 16, 8), ("smoke_dual", 24, 48, 8)]

# (name, n, p, t, chunk_rows) for the streamed fused-vs-unfused A/B.  Kept
# to the primal shapes: the kernel tier lives in the streamed masked
# update, which the dual path never routes through.
STREAMED_SHAPES = [
    ("small", 512, 128, 256, 128),
    ("medium", 1024, 256, 512, 256),
]
SMOKE_STREAMED_SHAPES = [("smoke", 96, 16, 8, 32)]


def timed(fn, reps: int) -> float:
    import jax
    jax.block_until_ready(fn())  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.time() - t0) / reps * 1e6  # µs


def bench_shape(name: str, n: int, p: int, t: int, n_folds: int,
                reps: int) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.core import ridge

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    X = jax.random.normal(k1, (n, p), jnp.float32)
    W = jax.random.normal(k2, (p, t), jnp.float32) / np.sqrt(p)
    Y = X @ W + 0.1 * jax.random.normal(k3, (n, t), jnp.float32)
    cfg = ridge.RidgeCVConfig(n_folds=n_folds)

    seed_us = timed(lambda: ridge.ridge_cv_reference(X, Y, cfg), reps)
    folded_us = timed(lambda: ridge.ridge_cv(X, Y, cfg), reps)

    ref = ridge.ridge_cv_reference(X, Y, cfg)
    new = ridge.ridge_cv(X, Y, cfg)
    lambda_match = float(ref.best_lambda) == float(new.best_lambda)
    max_err = float(np.max(np.abs(np.asarray(ref.weights) -
                                  np.asarray(new.weights))))
    row = {"name": name, "n": n, "p": p, "t": t, "n_folds": n_folds,
           "seed_us": round(seed_us, 1), "folded_us": round(folded_us, 1),
           "speedup": round(seed_us / folded_us, 2),
           "lambda_match": lambda_match,
           "max_weight_err": max_err}
    print(f"foldstats_{name},{folded_us:.1f},"
          f"seed_us={seed_us:.1f};speedup={row['speedup']:.2f};"
          f"lambda_match={lambda_match};max_weight_err={max_err:.2e}",
          flush=True)
    if not lambda_match:
        raise SystemExit(f"λ selection diverged on {name}")
    return row


def bench_streamed(name: str, n: int, p: int, t: int, chunk_rows: int,
                   n_folds: int, reps: int) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.core import foldstats, ridge
    from repro.kernels.ops import _interpret
    from repro.launch.roofline_report import encoding_roofline

    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    X = jax.random.normal(k1, (n, p), jnp.float32)
    Y = jax.random.normal(k2, (n, t), jnp.float32)
    chunks = [(X[i:i + chunk_rows], Y[i:i + chunk_rows])
              for i in range(0, n, chunk_rows)]

    def run(up: bool):
        return foldstats.compute_chunked(iter(chunks), n, n_folds,
                                         chunk_rows=chunk_rows,
                                         use_pallas=up).G

    unfused_us = timed(lambda: run(False), reps)
    fused_us = timed(lambda: run(True), reps)

    cfg = ridge.RidgeCVConfig(n_folds=n_folds)
    lam = [float(ridge.ridge_cv_from_stats(
        foldstats.compute_chunked(iter(chunks), n, n_folds,
                                  chunk_rows=chunk_rows, use_pallas=up),
        cfg).best_lambda) for up in (False, True)]
    lambda_match = lam[0] == lam[1]
    tier = "interpret" if _interpret() else "compiled"
    roof = encoding_roofline(n, p, t, r=len(cfg.lambdas), n_folds=n_folds,
                             wall_s=min(unfused_us, fused_us) * 1e-6)
    row = {"name": f"{name}_streamed", "n": n, "p": p, "t": t,
           "n_folds": n_folds, "chunk_rows": chunk_rows,
           "unfused_us": round(unfused_us, 1),
           "fused_us": round(fused_us, 1),
           "fused_speedup": round(unfused_us / fused_us, 3),
           "kernel_tier": tier, "lambda_match": lambda_match,
           "roofline": roof}
    print(f"foldstats_{name}_streamed,{fused_us:.1f},"
          f"unfused_us={unfused_us:.1f};tier={tier};"
          f"lambda_match={lambda_match};"
          f"bottleneck={roof['bottleneck']}", flush=True)
    if not lambda_match:
        raise SystemExit(f"λ selection diverged fused-vs-unfused on {name}")
    return row


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shape, 1 rep — perf-path import/parity guard")
    ap.add_argument("--use-pallas", action="store_true",
                    help="require the AUTO kernel tier to engage (sets "
                         "REPRO_PALLAS_FORCE_INTERPRET=1 if unset); exits "
                         "non-zero on silent fallback")
    ap.add_argument("--n-folds", type=int, default=5)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--out", default=None,
                    help="output JSON (default: BENCH_foldstats.json, or "
                         "BENCH_foldstats_smoke.json with --smoke so a CI "
                         "smoke never clobbers the real trajectory)")
    args = ap.parse_args()
    if args.out is None:
        name = ("BENCH_foldstats_smoke.json" if args.smoke
                else "BENCH_foldstats.json")
        args.out = os.path.join(REPO, name)

    if args.use_pallas:
        os.environ.setdefault("REPRO_PALLAS_FORCE_INTERPRET", "1")
        from repro.encoding import dispatch
        from repro.encoding.config import EncoderConfig
        cfg = EncoderConfig()  # use_pallas=None — the auto default
        if not cfg.resolve_use_pallas():
            raise SystemExit("--use-pallas: auto kernel tier did not "
                             "engage (silent fallback)")
        d = dispatch.resolve(cfg, 512, 128, 256, 1)
        if not d.use_pallas:
            raise SystemExit("--use-pallas: dispatch dropped the kernel "
                             f"tier (silent fallback): {d.rationale}")
        print(f"# kernel tier engaged: {d.rationale}")

    shapes = SMOKE_SHAPES if args.smoke else SHAPES
    streamed = SMOKE_STREAMED_SHAPES if args.smoke else STREAMED_SHAPES
    reps = 1 if args.smoke else args.reps
    print("name,us_per_call,derived")
    rows = [bench_shape(name, n, p, t, args.n_folds, reps)
            for name, n, p, t in shapes]
    rows += [bench_streamed(name, n, p, t, c, args.n_folds, reps)
             for name, n, p, t, c in streamed]
    payload = {"n_folds": args.n_folds, "smoke": args.smoke, "rows": rows}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
