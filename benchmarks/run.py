"""Benchmark harness — one benchmark per paper table/figure.

Outputs ``name,us_per_call,derived`` CSV lines (harness convention).

Mapping to the paper:
  fig4_encoding_quality   — §4.1: encoding r in responsive vs other targets
  fig5_null_permutation   — §4.2: aligned vs shuffled-feature encoding
  fig6_blas_analog        — §4.3: BLAS-choice analog — XLA matmul vs the
                            Pallas fused path at several problem sizes
  fig7_thread_scaling     — §4.4: single-node parallel-efficiency analog
                            (per-target cost amortisation in the mutualised
                            RidgeCV: the T_M plateau)
  fig8_mor_overhead       — §4.5: MOR vs mutualised ridge (measured, small)
  fig9_bmor_scaling       — §4.6: B-MOR training time vs #shards (measured)
  fig10_bmor_speedup      — §4.6: DSU speed-up ratio vs the §3 model
  table1_complexity       — §3: T_M/T_W/T_MOR/T_B-MOR at paper workloads
  roofline_*              — §Roofline terms surfaced from dry-run records

Distributed rows run in a subprocess with virtual host devices so this
process keeps the 1-device policy.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

ROWS: list[tuple[str, float, str]] = []
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def row(name: str, us: float, derived: str = "") -> None:
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def timed(fn, reps=3):
    import jax
    jax.block_until_ready(fn())  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.time() - t0) / reps * 1e6  # µs


# ---------------------------------------------------------------------------

def bench_quality():
    import jax
    import jax.numpy as jnp
    from repro.core import scoring
    from repro.data import fmri
    from repro.encoding import BrainEncoder

    spec = fmri.SubjectSpec(n=1200, p=128, t=512)
    X, Y, mask = fmri.generate(jax.random.PRNGKey(0), spec)
    tr, te = scoring.train_test_split_indices(jax.random.PRNGKey(1), spec.n)
    Xtr, Ytr = X[tr], Y[tr]

    enc = BrainEncoder()                      # auto → single-shard ridge
    us = timed(lambda: enc.fit(Xtr, Ytr).weights_, reps=2)
    r = enc.score(X[te], Y[te])
    m = np.asarray(mask)
    row("fig4_encoding_quality", us,
        f"r_responsive={r[m].mean():.3f};r_other={r[~m].mean():.3f};"
        f"lambda={float(enc.report_.best_lambda[0])}")

    null = scoring.null_permutation_scores(jax.random.PRNGKey(2), X[te],
                                           Y[te], enc.weights_, n_perms=10)
    row("fig5_null_permutation", 0.0,
        f"null_abs_r={float(jnp.mean(jnp.abs(null))):.4f};"
        f"aligned_r={r[m].mean():.3f}")


def bench_blas_analog():
    """XLA-fused vs Pallas-kernel gram (the 'which BLAS' analog; on this CPU
    container the Pallas number is interpret-mode and NOT indicative — the
    comparison that matters runs on TPU where the kernel compiles)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref

    for n, p in ((2048, 128), (4096, 256)):
        X = jax.random.normal(jax.random.PRNGKey(0), (n, p), jnp.float32)
        us_xla = timed(lambda: ref.gram(X))
        us_pl = timed(lambda: ops.gram(X), reps=1)
        row(f"fig6_blas_analog_gram_n{n}_p{p}", us_xla,
            f"pallas_interpret_us={us_pl:.0f}")


def bench_thread_scaling():
    """T_M amortisation: per-target cost falls as targets/batch grows — the
    single-node efficiency effect behind the paper's thread plateau.
    p is large so the factorisation term T_M ∝ p²n genuinely dominates."""
    import jax
    import jax.numpy as jnp
    from repro.encoding import BrainEncoder

    n, p = 1024, 384
    X = jax.random.normal(jax.random.PRNGKey(0), (n, p), jnp.float32)
    base = None
    for t in (16, 128, 1024):
        Y = jax.random.normal(jax.random.PRNGKey(1), (n, t), jnp.float32)
        enc = BrainEncoder(solver="ridge", n_folds=3)
        us = timed(lambda: enc.fit(X, Y).weights_, reps=2)
        per_target = us / t
        base = base or per_target
        row(f"fig7_tm_amortisation_t{t}", us,
            f"us_per_target={per_target:.2f};gain_vs_t16={base/per_target:.2f}")


def bench_complexity_table():
    from repro.core import complexity
    for name, w in complexity.PAPER_WORKLOADS.items():
        row(f"table1_complexity_{name}", 0.0,
            f"T_single={complexity.t_ridge_single(w):.3e};"
            f"T_MOR_c8={complexity.t_mor(w, 8):.3e};"
            f"T_BMOR_c8={complexity.t_bmor(w, 8):.3e};"
            f"DSU_c8={complexity.predicted_speedup_bmor(w, 8):.1f}")


def bench_distributed():
    """fig8/9/10 need >1 device → subprocess with virtual host devices."""
    script = os.path.join(REPO, "benchmarks", "distributed_bench.py")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, script], capture_output=True,
                          text=True, env=env, timeout=2400)
    if proc.returncode != 0:
        print(proc.stderr[-2000:], file=sys.stderr)
        row("fig8_mor_overhead", -1, "SUBPROCESS_FAILED")
        return
    for line in proc.stdout.splitlines():
        if line.startswith(("fig8", "fig9", "fig10")):
            name, us, derived = line.split(",", 2)
            row(name, float(us), derived)


def bench_roofline_table():
    """Surface dry-run roofline records if present (EXPERIMENTS §Roofline)."""
    path = os.path.join(REPO, "results", "dryrun.jsonl")
    if not os.path.exists(path):
        return
    from repro.launch.hlo_analysis import roofline_terms
    seen = set()
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            key = (r["arch"], r["shape"], r["mesh"], r.get("rules", "tp"))
            if r.get("mesh") != "16x16" or r.get("rules", "tp") != "tp" \
                    or key in seen:
                continue
            seen.add(key)
            terms = roofline_terms(r["flops"], r["hlo_bytes"],
                                   sum(r["collective_bytes"].values()))
            row(f"roofline_{r['arch']}_{r['shape']}",
                terms[f"t_{terms['bottleneck']}_s"] * 1e6,
                f"bottleneck={terms['bottleneck']};"
                f"tc={terms['t_compute_s']:.2e};"
                f"tm={terms['t_memory_s']:.2e};"
                f"tx={terms['t_collective_s']:.2e}")


def main() -> None:
    print("name,us_per_call,derived")
    bench_quality()
    bench_blas_analog()
    bench_thread_scaling()
    bench_complexity_table()
    bench_distributed()
    bench_roofline_table()
    print(f"# {len(ROWS)} benchmark rows", flush=True)


if __name__ == "__main__":
    main()
